//! Graph alignment + changed-subgraph extraction.
//!
//! [`align`] matches nodes of two graph versions by stable identity
//! ([`super::identity`]): an exact multiset pass over name-anchored
//! stable ids first, then a greedy propagation pass over structural
//! (name-blind) ids that recovers *renamed* regions — a renamed weight
//! matches when its surroundings agree, and each recovered match lets
//! its consumers match on the next sweep.
//!
//! [`GraphDiff`] turns a matching into the minimal dirty region at layer
//! granularity: the layers that own unmatched (changed/added/removed)
//! nodes, plus layers whose partition-level fingerprint
//! ([`crate::partition::fingerprint_slice`]) differs anyway. Everything
//! outside `dirty_layers` is re-derivable from a previous run's persisted
//! [`crate::diff::VerifyState`].

use super::identity::{stable_ids, structural_ids};
use crate::ir::{Graph, NodeId};
use crate::partition::{extract_layers, fingerprint_slice};
use rustc_hash::FxHashMap;
use std::collections::VecDeque;

/// A (partial) node matching between an old and a new graph version.
#[derive(Clone, Debug)]
pub struct NodeMatching {
    /// For each old node, the matched new node (None = removed/changed).
    pub old_to_new: Vec<Option<NodeId>>,
    /// For each new node, the matched old node (None = added/changed).
    pub new_to_old: Vec<Option<NodeId>>,
    /// Matches recovered by the rename-propagation pass (these differ in
    /// name-anchored identity but agree structurally and contextually).
    pub renamed: usize,
}

impl NodeMatching {
    /// Count of matched node pairs.
    pub fn matched(&self) -> usize {
        self.new_to_old.iter().filter(|m| m.is_some()).count()
    }
}

/// Align two graph versions node-for-node; see the module docs.
pub fn align(old: &Graph, new: &Graph) -> NodeMatching {
    let mut m = NodeMatching {
        old_to_new: vec![None; old.nodes.len()],
        new_to_old: vec![None; new.nodes.len()],
        renamed: 0,
    };

    // ---- pass 1: exact stable-id multiset matching ----
    // Duplicate ids (e.g. the same constant twice) match in emission
    // order, which is the order the builder re-emits them.
    let old_stable = stable_ids(old);
    let mut by_id: FxHashMap<u64, VecDeque<NodeId>> = FxHashMap::default();
    for (i, &id) in old_stable.iter().enumerate() {
        by_id.entry(id).or_default().push_back(NodeId(i as u32));
    }
    let new_stable = stable_ids(new);
    for (i, &id) in new_stable.iter().enumerate() {
        if let Some(q) = by_id.get_mut(&id) {
            if let Some(o) = q.pop_front() {
                m.old_to_new[o.idx()] = Some(NodeId(i as u32));
                m.new_to_old[i] = Some(o);
            }
        }
    }

    // ---- pass 2: greedy rename propagation over structural ids ----
    // Unmatched new nodes try unmatched old candidates with the same
    // name-blind structural id; a candidate is accepted when no already-
    // matched operand disagrees, preferring the one whose operands agree
    // the most. Each sweep can unlock further matches downstream, so
    // sweep until a fixpoint.
    let old_struct = structural_ids(old);
    let new_struct = structural_ids(new);
    let mut candidates: FxHashMap<u64, Vec<NodeId>> = FxHashMap::default();
    for (i, &id) in old_struct.iter().enumerate() {
        if m.old_to_new[i].is_none() {
            candidates.entry(id).or_default().push(NodeId(i as u32));
        }
    }
    loop {
        let mut advanced = false;
        for i in 0..new.nodes.len() {
            if m.new_to_old[i].is_some() {
                continue;
            }
            let Some(pool) = candidates.get(&new_struct[i]) else { continue };
            let n_node = &new.nodes[i];
            let mut best: Option<(usize, NodeId)> = None;
            for &o in pool {
                if m.old_to_new[o.idx()].is_some() {
                    continue;
                }
                let o_node = &old.nodes[o.idx()];
                if o_node.inputs.len() != n_node.inputs.len() {
                    continue;
                }
                let mut agree = 0usize;
                let mut disagree = false;
                for (oi, ni) in o_node.inputs.iter().zip(&n_node.inputs) {
                    match m.new_to_old[ni.idx()] {
                        Some(mapped) if mapped == *oi => agree += 1,
                        Some(_) => {
                            disagree = true;
                            break;
                        }
                        None => {}
                    }
                }
                if !disagree && best.map(|(a, _)| agree > a).unwrap_or(true) {
                    best = Some((agree, o));
                }
            }
            if let Some((_, o)) = best {
                m.old_to_new[o.idx()] = Some(NodeId(i as u32));
                m.new_to_old[i] = Some(o);
                m.renamed += 1;
                advanced = true;
            }
        }
        if !advanced {
            break;
        }
    }
    m
}

/// The layer-granular dirty region between two graph versions.
#[derive(Clone, Debug)]
pub struct GraphDiff {
    /// The underlying node matching.
    pub matching: NodeMatching,
    /// New-side nodes with no old counterpart.
    pub added: Vec<NodeId>,
    /// Old-side nodes with no new counterpart.
    pub removed: Vec<NodeId>,
    /// Layer tags that must re-verify, sorted ascending (untagged nodes
    /// live in the `u32::MAX` pseudo-layer, same as the partitioner).
    pub dirty_layers: Vec<u32>,
    /// Unmatched-node count per dirty layer (both sides combined) — the
    /// `delta_nodes` a diff-aware layer report carries.
    pub delta_by_layer: FxHashMap<u32, usize>,
}

impl GraphDiff {
    /// Diff two versions of a graph; see the module docs.
    pub fn compute(old: &Graph, new: &Graph) -> GraphDiff {
        let matching = align(old, new);
        let mut added = Vec::new();
        let mut removed = Vec::new();
        let mut delta_by_layer: FxHashMap<u32, usize> = FxHashMap::default();
        let mut dirty: Vec<u32> = Vec::new();
        let mut mark = |tag: u32, delta: &mut FxHashMap<u32, usize>| {
            *delta.entry(tag).or_insert(0) += 1;
        };
        for (i, mapped) in matching.new_to_old.iter().enumerate() {
            if mapped.is_none() {
                added.push(NodeId(i as u32));
                mark(new.nodes[i].meta.layer.unwrap_or(u32::MAX), &mut delta_by_layer);
            }
        }
        for (i, mapped) in matching.old_to_new.iter().enumerate() {
            if mapped.is_none() {
                removed.push(NodeId(i as u32));
                mark(old.nodes[i].meta.layer.unwrap_or(u32::MAX), &mut delta_by_layer);
            }
        }
        dirty.extend(delta_by_layer.keys().copied());

        // A layer can be dirty without unmatched nodes (reordered outputs,
        // boundary changes): cross-check partition fingerprints, which are
        // exactly what decides replay at verify time. Layers on one side
        // only are dirty by definition.
        let old_slices = extract_layers(old);
        let new_slices = extract_layers(new);
        let old_fp: FxHashMap<u32, u64> =
            old_slices.iter().map(|s| (s.layer, fingerprint_slice(s))).collect();
        let new_fp: FxHashMap<u32, u64> =
            new_slices.iter().map(|s| (s.layer, fingerprint_slice(s))).collect();
        for (tag, fp) in &new_fp {
            if old_fp.get(tag) != Some(fp) {
                dirty.push(*tag);
            }
        }
        for tag in old_fp.keys() {
            if !new_fp.contains_key(tag) {
                dirty.push(*tag);
            }
        }
        dirty.sort_unstable();
        dirty.dedup();
        GraphDiff { matching, added, removed, dirty_layers: dirty, delta_by_layer }
    }

    /// Total unmatched nodes across both sides.
    pub fn delta_nodes(&self) -> usize {
        self.added.len() + self.removed.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, Shape};

    fn model(scale: f64, wname: &str) -> Graph {
        let mut b = GraphBuilder::new("m", 1);
        b.layer(Some(0));
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 4]));
        let w = b.parameter(wname, Shape::new(DType::F32, vec![4, 4]));
        let h = b.matmul(x, w);
        b.layer(Some(1));
        let c = b.constant(scale, DType::F32);
        let cb = b.broadcast_scalar(c, vec![4, 4]);
        let y = b.mul(h, cb);
        b.layer(Some(2));
        let z = b.tanh(y);
        b.output(z);
        b.finish()
    }

    #[test]
    fn identical_graphs_align_fully_with_no_dirty_layers() {
        let g1 = model(2.0, "w");
        let g2 = model(2.0, "w");
        let d = GraphDiff::compute(&g1, &g2);
        assert_eq!(d.matching.matched(), g1.nodes.len());
        assert!(d.added.is_empty() && d.removed.is_empty());
        assert!(d.dirty_layers.is_empty(), "dirty: {:?}", d.dirty_layers);
    }

    #[test]
    fn one_constant_edit_dirties_exactly_its_layer() {
        let g1 = model(2.0, "w");
        let g2 = model(3.0, "w");
        let d = GraphDiff::compute(&g1, &g2);
        assert_eq!(d.dirty_layers, vec![1]);
        assert!(d.delta_nodes() > 0);
        assert!(d.delta_by_layer.keys().all(|&t| t == 1));
    }

    #[test]
    fn renamed_weight_is_recovered_by_propagation() {
        let g1 = model(2.0, "w_v1");
        let g2 = model(2.0, "w_v2");
        let d = GraphDiff::compute(&g1, &g2);
        assert_eq!(d.matching.matched(), g1.nodes.len(), "rename must align");
        assert!(d.matching.renamed >= 1);
        assert!(d.added.is_empty() && d.removed.is_empty());
        // fingerprints ignore parameter names, so nothing is dirty either
        assert!(d.dirty_layers.is_empty(), "dirty: {:?}", d.dirty_layers);
    }

    #[test]
    fn added_op_shows_up_as_added_nodes_in_its_layer() {
        let g1 = model(2.0, "w");
        let mut b = GraphBuilder::new("m", 1);
        b.layer(Some(0));
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 4]));
        let w = b.parameter("w", Shape::new(DType::F32, vec![4, 4]));
        let h = b.matmul(x, w);
        b.layer(Some(1));
        let c = b.constant(2.0, DType::F32);
        let cb = b.broadcast_scalar(c, vec![4, 4]);
        let y = b.mul(h, cb);
        let y = b.abs(y); // the extra op
        b.layer(Some(2));
        let z = b.tanh(y);
        b.output(z);
        let g2 = b.finish();
        let d = GraphDiff::compute(&g1, &g2);
        assert_eq!(d.dirty_layers, vec![1]);
        assert_eq!(d.added.len(), 1);
        assert!(d.removed.is_empty());
    }
}
