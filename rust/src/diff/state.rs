//! The persisted verification-state artifact (`verify --emit-state` /
//! `verify --against`).
//!
//! A [`VerifyState`] is what a verify run knows that a re-verify can
//! reuse: per layer, the pair fingerprint it verified under, its boundary
//! output relations, and the stable node identities of its members.
//! `verify --against` replays every layer whose fingerprint still matches
//! (out-relations seed the next layer exactly as a live verification
//! would — the semi-naive idiom: only facts downstream of the diff are
//! re-derived) and re-verifies the rest, reporting `delta_nodes` from the
//! stable-id multiset difference.
//!
//! The file is versioned and checksummed like the service's memo cache
//! (same [`crate::partition::FINGERPRINT_VERSION`] gate, same
//! degrade-to-cold contract): any skew, tamper or parse failure costs a
//! cold verify, never a wrong replay. Fingerprints and node ids are
//! written as fixed-width hex (JSON numbers are doubles and cannot carry
//! 64 bits).

use crate::error::{Result, ScalifyError};
use crate::ir::Graph;
use crate::partition::check_fingerprint_version;
use crate::report::json::Json;
use crate::report::{json_checksum, rel_summary_from_json, rel_summary_to_json};
use crate::verifier::boundary::RelSummary;
use rustc_hash::FxHashMap;

/// On-disk format version of the state artifact (independent of the
/// fingerprint scheme).
pub const STATE_FORMAT_VERSION: u32 = 1;

/// What one verified (or failed) layer left behind.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerState {
    /// Layer tag (`u32::MAX` = the untagged pseudo-layer).
    pub layer: u32,
    /// Pipeline stage, when one owns the layer.
    pub stage: Option<u32>,
    /// The pair fingerprint this layer verified under — replay requires
    /// an exact match, which is what makes a stale state *safe*: a state
    /// from the wrong model simply reuses nothing.
    pub fingerprint: u64,
    /// Whether the layer verified (failed layers never replay).
    pub verified: bool,
    /// Boundary output relations, seeding the next layer on replay.
    pub out_rels: Vec<RelSummary>,
    /// E-graph size of the original verification (stats).
    pub egraph_nodes: usize,
    /// E-graph class count of the original verification (stats).
    pub egraph_classes: usize,
    /// Stable ids of the layer's distributed-side nodes
    /// ([`crate::diff::stable_ids`]); `delta_nodes` is the multiset
    /// difference against the new version's ids.
    pub node_ids: Vec<u64>,
}

/// A whole run's persisted verification state.
#[derive(Clone, Debug, PartialEq)]
pub struct VerifyState {
    /// Distributed-graph name (informational; mismatches warn upstream).
    pub model: String,
    /// SPMD width the state was computed under.
    pub num_cores: u32,
    /// Device mesh the state was computed under.
    pub mesh: Vec<u32>,
    /// Verdict status of the producing run (`verified` / `unverified` /
    /// `resource-exhausted`).
    pub status: String,
    /// Per-layer state, in verification order.
    pub layers: Vec<LayerState>,
}

impl VerifyState {
    /// Look up a layer by tag.
    pub fn layer(&self, tag: u32) -> Option<&LayerState> {
        self.layers.iter().find(|l| l.layer == tag)
    }

    /// True when `pair_dist` matches the graph this state was computed
    /// from (width + mesh); callers warn and verify cold otherwise.
    pub fn matches_graph(&self, dist: &Graph) -> bool {
        self.num_cores == dist.num_cores && self.mesh == dist.mesh
    }

    /// JSON encoding (versioned + checksummed envelope).
    pub fn to_json(&self) -> Json {
        let layers = Json::Arr(self.layers.iter().map(layer_state_to_json).collect());
        let checksum = json_checksum(&layers);
        Json::Obj(vec![
            ("format".into(), Json::Num(STATE_FORMAT_VERSION as f64)),
            (
                "fingerprint_version".into(),
                Json::Num(crate::partition::FINGERPRINT_VERSION as f64),
            ),
            ("checksum".into(), Json::Str(checksum)),
            (
                "graph".into(),
                Json::Obj(vec![
                    ("name".into(), Json::Str(self.model.clone())),
                    ("num_cores".into(), Json::Num(self.num_cores as f64)),
                    (
                        "mesh".into(),
                        Json::Arr(
                            self.mesh.iter().map(|&a| Json::Num(a as f64)).collect(),
                        ),
                    ),
                ]),
            ),
            ("status".into(), Json::Str(self.status.clone())),
            ("layers".into(), layers),
        ])
    }

    /// Pretty-printed JSON document.
    pub fn to_json_string(&self) -> String {
        self.to_json().render_pretty()
    }

    /// Decode a state document. Errors describe why the state is unusable;
    /// every caller treats that as a cold start plus a warning, mirroring
    /// the service cache (same fingerprint-version gate, same contract).
    pub fn from_json(doc: &Json) -> std::result::Result<VerifyState, String> {
        let format = doc.u64_at("format").ok_or("missing 'format' version")?;
        if format != STATE_FORMAT_VERSION as u64 {
            return Err(format!(
                "state format v{format} (this build reads v{STATE_FORMAT_VERSION})"
            ));
        }
        check_fingerprint_version(doc)?;
        let layers_doc = doc
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or("missing 'layers' array")?;
        let expected = doc.str_at("checksum").ok_or("missing 'checksum'")?;
        let actual = json_checksum(&Json::Arr(layers_doc.to_vec()));
        if actual != expected {
            return Err(format!(
                "checksum mismatch (file says {expected}, contents hash to {actual})"
            ));
        }
        let graph = doc.get("graph").ok_or("missing 'graph' descriptor")?;
        let model = graph.str_at("name").unwrap_or("").to_string();
        let num_cores =
            graph.u64_at("num_cores").ok_or("graph descriptor is missing 'num_cores'")?
                as u32;
        let mesh = graph
            .get("mesh")
            .and_then(Json::as_arr)
            .map(|arr| arr.iter().filter_map(Json::as_u64).map(|a| a as u32).collect())
            .unwrap_or_default();
        let status = doc.str_at("status").unwrap_or("unknown").to_string();
        let layers = layers_doc
            .iter()
            .map(layer_state_from_json)
            .collect::<std::result::Result<Vec<_>, String>>()?;
        Ok(VerifyState { model, num_cores, mesh, status, layers })
    }

    /// Parse a state document from text.
    pub fn parse(text: &str) -> std::result::Result<VerifyState, String> {
        let doc = Json::parse(text).map_err(|e| format!("corrupted JSON: {e}"))?;
        VerifyState::from_json(&doc)
    }

    /// Load from a file; the error string is caller-facing ("why am I
    /// verifying cold").
    pub fn load(path: &std::path::Path) -> std::result::Result<VerifyState, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("state file {} is unreadable ({e})", path.display()))?;
        VerifyState::parse(&text)
            .map_err(|why| format!("ignoring state file {} ({why})", path.display()))
    }

    /// Save to a file (temp + rename, like the service cache).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, self.to_json_string()).map_err(|e| {
            ScalifyError::runtime(format!("writing state {}: {e}", tmp.display()))
        })?;
        std::fs::rename(&tmp, path).map_err(|e| {
            ScalifyError::runtime(format!("renaming state into {}: {e}", path.display()))
        })
    }
}

fn layer_state_to_json(l: &LayerState) -> Json {
    let mut fields = vec![
        ("layer".into(), Json::Num(l.layer as f64)),
        (
            "stage".into(),
            l.stage.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null),
        ),
        ("fp".into(), Json::Str(format!("{:016x}", l.fingerprint))),
        ("verified".into(), Json::Bool(l.verified)),
        (
            "out_rels".into(),
            Json::Arr(l.out_rels.iter().map(rel_summary_to_json).collect()),
        ),
        ("egraph_nodes".into(), Json::Num(l.egraph_nodes as f64)),
        ("egraph_classes".into(), Json::Num(l.egraph_classes as f64)),
    ];
    fields.push((
        "node_ids".into(),
        Json::Arr(
            l.node_ids.iter().map(|id| Json::Str(format!("{id:016x}"))).collect(),
        ),
    ));
    Json::Obj(fields)
}

fn layer_state_from_json(doc: &Json) -> std::result::Result<LayerState, String> {
    let hex64 = |s: &str| {
        u64::from_str_radix(s, 16).map_err(|_| format!("bad hex id '{s}'"))
    };
    let fingerprint = hex64(doc.str_at("fp").ok_or("layer state is missing 'fp'")?)?;
    let out_rels = doc
        .get("out_rels")
        .and_then(Json::as_arr)
        .ok_or("layer state is missing 'out_rels'")?
        .iter()
        .map(rel_summary_from_json)
        .collect::<std::result::Result<Vec<_>, String>>()?;
    let node_ids = doc
        .get("node_ids")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .map(|j| j.as_str().ok_or("node id is not a string".to_string()).and_then(hex64))
                .collect::<std::result::Result<Vec<_>, String>>()
        })
        .transpose()?
        .unwrap_or_default();
    Ok(LayerState {
        layer: doc.u64_at("layer").ok_or("layer state is missing 'layer'")? as u32,
        stage: doc.get("stage").and_then(Json::as_u64).map(|s| s as u32),
        fingerprint,
        verified: doc.bool_at("verified").ok_or("layer state is missing 'verified'")?,
        out_rels,
        egraph_nodes: doc.u64_at("egraph_nodes").unwrap_or(0) as usize,
        egraph_classes: doc.u64_at("egraph_classes").unwrap_or(0) as usize,
        node_ids,
    })
}

/// Group a graph's stable node ids by layer tag (the granularity
/// [`LayerState::node_ids`] stores). With `partitioned == false` every
/// node lands in the `u32::MAX` pseudo-layer with no-cut identities, to
/// match the whole-graph pseudo-layer the verifier uses.
pub fn layer_node_ids(g: &Graph, partitioned: bool) -> FxHashMap<u32, Vec<u64>> {
    let mut by_layer: FxHashMap<u32, Vec<u64>> = FxHashMap::default();
    if partitioned {
        let ids = super::identity::stable_ids(g);
        for (n, id) in g.nodes.iter().zip(ids) {
            by_layer.entry(n.meta.layer.unwrap_or(u32::MAX)).or_default().push(id);
        }
    } else {
        by_layer.insert(u32::MAX, super::identity::stable_ids_unpartitioned(g));
    }
    by_layer
}

/// Size of the symmetric multiset difference between two id sets — the
/// `delta_nodes` of a re-verified layer.
pub fn id_multiset_delta(old: &[u64], new: &[u64]) -> usize {
    let mut counts: FxHashMap<u64, i64> = FxHashMap::default();
    for &id in old {
        *counts.entry(id).or_insert(0) += 1;
    }
    for &id in new {
        *counts.entry(id).or_insert(0) -= 1;
    }
    counts.values().map(|c| c.unsigned_abs() as usize).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ReduceKind;

    fn sample_state() -> VerifyState {
        VerifyState {
            model: "llama-tiny@tp2".into(),
            num_cores: 2,
            mesh: vec![2],
            status: "verified".into(),
            layers: vec![
                LayerState {
                    layer: 0,
                    stage: None,
                    fingerprint: 0xdead_beef_1234_5678,
                    verified: true,
                    out_rels: vec![
                        RelSummary::Duplicate,
                        RelSummary::Sharded { dim: 1, parts: 2, axis: 0 },
                        RelSummary::MeshSharded { entries: vec![(0, 2, 0), (1, 2, 1)] },
                        RelSummary::Partial { kind: ReduceKind::Add, axes: 1 },
                    ],
                    egraph_nodes: 77,
                    egraph_classes: 33,
                    node_ids: vec![1, 0xffff_ffff_ffff_fffe, 42],
                },
                LayerState {
                    layer: u32::MAX,
                    stage: Some(1),
                    fingerprint: 7,
                    verified: false,
                    out_rels: vec![],
                    egraph_nodes: 0,
                    egraph_classes: 0,
                    node_ids: vec![],
                },
            ],
        }
    }

    #[test]
    fn state_round_trips_through_json() {
        let s = sample_state();
        let back = VerifyState::parse(&s.to_json_string()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn state_round_trips_through_a_file() {
        let path = std::env::temp_dir()
            .join(format!("scalify-state-test-{}.json", std::process::id()));
        let s = sample_state();
        s.save(&path).unwrap();
        let back = VerifyState::load(&path).unwrap();
        assert_eq!(back, s);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fingerprint_version_skew_is_rejected_like_the_cache() {
        let mut doc = sample_state().to_json();
        if let Json::Obj(fields) = &mut doc {
            for (k, v) in fields.iter_mut() {
                if k == "fingerprint_version" {
                    *v = Json::Num(9999.0);
                }
            }
        }
        let err = VerifyState::from_json(&doc).unwrap_err();
        assert!(err.contains("scheme v9999"), "{err}");
    }

    #[test]
    fn tampered_layers_fail_the_checksum() {
        let text = sample_state().to_json_string();
        let tampered = text.replace("deadbeef12345678", "deadbeef12345679");
        assert_ne!(text, tampered);
        let err = VerifyState::parse(&tampered).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn multiset_delta_counts_both_sides() {
        assert_eq!(id_multiset_delta(&[1, 2, 2, 3], &[1, 2, 4]), 3); // -2,-3,+4
        assert_eq!(id_multiset_delta(&[], &[]), 0);
        assert_eq!(id_multiset_delta(&[5], &[5]), 0);
    }
}
