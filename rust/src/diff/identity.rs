//! Version-stable node identities (the diff front end's anchor).
//!
//! Two emissions of "the same" model — a config tweak apart, a framework
//! upgrade apart — must align node-for-node before a diff can be small.
//! Serial node ids are useless for that (inserting one op renumbers
//! everything downstream), so each node gets a **stable id**: a
//! deterministic [`StableHasher`] digest of its op kind and attributes,
//! its shape, and the stable ids of its same-layer operands.
//!
//! Cross-layer operands are hashed as opaque *boundary markers* (shape +
//! dtype only), mirroring how [`crate::partition::extract_layers`] imports
//! cross-layer values as fresh parameters. That cut is what keeps the
//! dirty region of an edit confined to the edited layer: a changed
//! attention scale perturbs the stable ids of its own layer's downstream
//! cone and nothing else, exactly matching the layer granularity at which
//! [`crate::partition::fingerprint_pair`] decides reuse.
//!
//! Two flavors:
//! * [`stable_ids`] anchors parameters on their *names* when available
//!   (`l3.q_proj` survives reordering of the parameter list), and
//! * [`structural_ids`] anchors parameters on their positional index only
//!   — the fallback identity used by the greedy rename-propagation pass
//!   in [`crate::diff::align`], where name anchors have already failed.

use crate::ir::{Graph, Op};
use crate::partition::StableHasher;
use std::hash::{Hash, Hasher};

/// Name-anchored stable id per node, indexed by node position.
///
/// Deterministic across processes and graph re-emissions: a pure function
/// of op structure, shapes, layer tags and (for named parameters) names.
pub fn stable_ids(g: &Graph) -> Vec<u64> {
    ids_inner(g, true, true)
}

/// Position-anchored structural id per node (parameter names ignored).
///
/// Renaming every weight leaves these unchanged, so they are the
/// candidate pool for rename propagation.
pub fn structural_ids(g: &Graph) -> Vec<u64> {
    ids_inner(g, true, false)
}

/// Stable ids with all nodes treated as one region (no layer cut) — used
/// when the verifier runs unpartitioned, so identity granularity matches
/// the whole-graph pseudo-layer.
pub fn stable_ids_unpartitioned(g: &Graph) -> Vec<u64> {
    ids_inner(g, false, true)
}

fn ids_inner(g: &Graph, use_layer_tags: bool, name_anchored: bool) -> Vec<u64> {
    let mut ids: Vec<u64> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let tag = if use_layer_tags { n.meta.layer } else { None };
        let mut h = StableHasher::new();
        match &n.op {
            Op::Parameter { name, .. } if name_anchored && !name.is_empty() => {
                ("param", name).hash(&mut h)
            }
            Op::Parameter { index, .. } => ("param", index).hash(&mut h),
            op => format!("{op:?}").hash(&mut h),
        }
        n.shape.dims.hash(&mut h);
        (n.shape.dtype as u8).hash(&mut h);
        for i in &n.inputs {
            let inp = &g.nodes[i.idx()];
            let inp_tag = if use_layer_tags { inp.meta.layer } else { None };
            if inp_tag == tag {
                // operands are defined before use, so this id exists
                ids[i.idx()].hash(&mut h);
            } else {
                // cross-layer value: opaque boundary marker, so edits in
                // the producing layer don't cascade into this one
                "boundary".hash(&mut h);
                inp.shape.dims.hash(&mut h);
                (inp.shape.dtype as u8).hash(&mut h);
            }
        }
        ids.push(h.finish());
    }
    ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, Shape};

    fn two_layer_graph(scale: f64) -> Graph {
        let mut b = GraphBuilder::new("g", 1);
        b.layer(Some(0));
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 8]));
        let c = b.constant(scale, DType::F32);
        let cb = b.broadcast_scalar(c, vec![4, 8]);
        let s = b.mul(x, cb);
        b.layer(Some(1));
        let e = b.exp(s);
        b.output(e);
        b.finish()
    }

    #[test]
    fn ids_are_deterministic_and_value_sensitive() {
        let g1 = two_layer_graph(2.0);
        let g2 = two_layer_graph(2.0);
        assert_eq!(stable_ids(&g1), stable_ids(&g2));
        let g3 = two_layer_graph(3.0);
        let a = stable_ids(&g1);
        let b = stable_ids(&g3);
        assert_ne!(a, b, "constant edit must change ids");
    }

    #[test]
    fn layer_cut_confines_an_edit_to_its_own_layer() {
        let a = stable_ids(&two_layer_graph(2.0));
        let b = stable_ids(&two_layer_graph(3.0));
        // layer 0: constant + downstream broadcast/mul change; the
        // parameter upstream of the edit does not
        assert_eq!(a[0], b[0], "parameter is upstream of the edit");
        assert_ne!(a[1], b[1], "edited constant");
        assert_ne!(a[2], b[2], "downstream broadcast inside the layer");
        assert_ne!(a[3], b[3], "downstream mul inside the layer");
        // layer 1 consumes the changed value across the boundary — its
        // ids must NOT change (boundary marker is shape-only)
        assert_eq!(a[4], b[4], "cross-layer consumer is cut off");
    }

    #[test]
    fn structural_ids_ignore_parameter_names() {
        let named = |name: &str| {
            let mut b = GraphBuilder::new("g", 1);
            let x = b.parameter(name, Shape::new(DType::F32, vec![4]));
            let y = b.neg(x);
            b.output(y);
            b.finish()
        };
        let g1 = named("w_old");
        let g2 = named("w_new");
        assert_ne!(stable_ids(&g1)[0], stable_ids(&g2)[0]);
        assert_eq!(structural_ids(&g1), structural_ids(&g2));
    }
}
