//! Incremental verify-on-diff: the graph-diff front end.
//!
//! Production frameworks re-emit *almost-identical* graphs constantly —
//! a config tweak, a framework upgrade, one fused op changed — and the
//! question a user wants answered is "is v2 still equivalent, and if
//! not, which of MY edits broke it", in milliseconds rather than a full
//! re-verification. This module makes re-verification incremental end
//! to end:
//!
//! * [`identity`] — version-stable node ids (op kind + shape +
//!   same-layer operand fingerprints + names where available), cut at
//!   layer boundaries so an edit's dirty cone stays inside its layer;
//! * [`align`] — node matching between two graph versions (exact
//!   stable-id pass + greedy rename propagation) and [`GraphDiff`], the
//!   layer-granular changed-subgraph extraction;
//! * [`state`] — the persisted [`VerifyState`] artifact: per-layer pair
//!   fingerprints, boundary out-relations and stable node ids from a
//!   previous run. `Session::verify_against` replays unchanged layers
//!   from it and re-derives only downstream of the diff (semi-naive:
//!   a changed layer's new out-relations change the next layer's
//!   fingerprint, which re-verifies in turn — the re-derivation frontier
//!   follows the facts, not the whole graph);
//! * [`edit`] — deterministic one-op edits driving `bench --diff` and
//!   the CI incremental job.
//!
//! Surfaces: `scalify verify/model --against/--emit-state`, the
//! `verify_diff` service request, diff-aware [`crate::verifier::LayerReport`]
//! fields (`reused` / `reverified` / `delta_nodes`) and the
//! `scalify bench --diff` tier.

pub mod align;
pub mod edit;
pub mod identity;
pub mod state;

pub use align::{align, GraphDiff, NodeMatching};
pub use edit::{one_op_edit, one_sided_edit};
pub use identity::{stable_ids, structural_ids};
pub use state::{
    id_multiset_delta, layer_node_ids, LayerState, VerifyState, STATE_FORMAT_VERSION,
};
