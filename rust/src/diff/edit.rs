//! Scripted one-op edits for the incremental bench/CI path.
//!
//! `scalify model --edit-layer N` and `scalify bench --diff` need a
//! deterministic "v2" of a zoo model: [`one_op_edit`] nudges every
//! scalar constant tagged with layer `N` (the attention scale, in the
//! Llama zoo) by `+1.0`. Applied to **both** sides of a pair the
//! edit preserves equivalence — the incremental re-verify must localize
//! the work to layer `N` and still say VERIFIED; applied to the
//! distributed side only it injects a divergence that must localize to
//! the same site incrementally as cold.

use crate::error::{Result, ScalifyError};
use crate::ir::{ConstVal, Graph, Op};
use crate::verifier::GraphPair;

/// Bump every scalar constant in layer `layer` by `+1.0`. Returns how
/// many constants changed.
fn bump_constants(g: &mut Graph, layer: u32) -> usize {
    let mut changed = 0;
    for n in g.nodes.iter_mut() {
        if n.meta.layer != Some(layer) {
            continue;
        }
        if let Op::Constant(ConstVal::Scalar(v)) = &n.op {
            n.op = Op::Constant(ConstVal::Scalar(v + 1.0));
            changed += 1;
        }
    }
    changed
}

/// The equivalence-preserving v1→v2 edit: bump layer `layer`'s scalar
/// constants on *both* sides. Errors when the layer has no scalar
/// constant to edit (the edit would be a no-op and the bench dishonest).
pub fn one_op_edit(pair: &GraphPair, layer: u32) -> Result<GraphPair> {
    let mut edited = pair.clone();
    let nb = bump_constants(&mut edited.base, layer);
    let nd = bump_constants(&mut edited.dist, layer);
    if nb == 0 || nd == 0 {
        return Err(ScalifyError::model_spec(format!(
            "layer {layer} has no scalar constant to edit \
             (base changed {nb}, dist changed {nd})"
        )));
    }
    Ok(edited)
}

/// The divergence-injecting edit: bump only the *distributed* side, so
/// v2 is genuinely wrong in layer `layer` and both the cold and the
/// incremental path must flag that layer.
pub fn one_sided_edit(pair: &GraphPair, layer: u32) -> Result<GraphPair> {
    let mut edited = pair.clone();
    let nd = bump_constants(&mut edited.dist, layer);
    if nd == 0 {
        return Err(ScalifyError::model_spec(format!(
            "layer {layer} has no scalar constant to edit on the distributed side"
        )));
    }
    Ok(edited)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelgen::{llama_pair, LlamaConfig, Parallelism};

    #[test]
    fn both_sided_edit_changes_exactly_one_layers_fingerprint() {
        use crate::partition::{extract_layers, fingerprint_slice};
        let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
        let edited = one_op_edit(&pair, 1).unwrap();
        let before: Vec<_> =
            extract_layers(&pair.dist).iter().map(fingerprint_slice).collect();
        let after: Vec<_> =
            extract_layers(&edited.dist).iter().map(fingerprint_slice).collect();
        assert_eq!(before.len(), after.len());
        let diffs: Vec<usize> = before
            .iter()
            .zip(&after)
            .enumerate()
            .filter(|(_, (a, b))| a != b)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(diffs.len(), 1, "exactly one slice changes: {diffs:?}");
    }

    #[test]
    fn editing_a_missing_layer_is_an_error() {
        let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
        assert!(one_op_edit(&pair, 999).is_err());
    }
}
