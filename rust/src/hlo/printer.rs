//! [`crate::ir::Graph`] → HLO-text printer.
//!
//! Output parses back through [`super::parse_hlo_module`] (round-trip
//! tested) and — for collective-free graphs — through XLA 0.5.1's own text
//! parser, so printed baseline graphs can be compiled and executed by the
//! PJRT runtime for numerical cross-checks.

use crate::ir::{CmpKind, ConstVal, Graph, Op, ReduceKind};
use std::fmt::Write;

fn region_name(kind: ReduceKind) -> &'static str {
    match kind {
        ReduceKind::Add => "region_add",
        ReduceKind::Max => "region_max",
        ReduceKind::Min => "region_min",
        ReduceKind::Mul => "region_mul",
    }
}

fn reduce_init(kind: ReduceKind) -> &'static str {
    match kind {
        ReduceKind::Add => "0",
        ReduceKind::Max => "-inf",
        ReduceKind::Min => "inf",
        ReduceKind::Mul => "1",
    }
}

fn fmt_f64(v: f64) -> String {
    if v == f64::INFINITY {
        "inf".into()
    } else if v == f64::NEG_INFINITY {
        "-inf".into()
    } else if v.is_nan() {
        "nan".into()
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn usize_list(xs: &[usize]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("{{{}}}", items.join(","))
}

/// Print a graph as an HLO module.
pub fn print_hlo_module(g: &Graph) -> String {
    let mut out = String::new();
    if g.mesh.is_empty() {
        writeln!(out, "HloModule {}", g.name).unwrap();
    } else {
        // mesh axes ride as a module attribute (our dialect, like the
        // `stage=` metadata) so subgroup replica_groups stay
        // interpretable after a round trip
        let axes: Vec<String> = g.mesh.iter().map(|a| a.to_string()).collect();
        writeln!(out, "HloModule {}, mesh={{{}}}", g.name, axes.join(",")).unwrap();
    }
    writeln!(out).unwrap();

    // Which reduction regions do we need?
    let mut kinds: Vec<ReduceKind> = Vec::new();
    for n in &g.nodes {
        let k = match &n.op {
            Op::Reduce { kind, .. }
            | Op::AllReduce { kind, .. }
            | Op::ReduceScatter { kind, .. } => Some(*kind),
            _ => None,
        };
        if let Some(k) = k {
            if !kinds.contains(&k) {
                kinds.push(k);
            }
        }
    }
    for k in &kinds {
        let dt = "f32"; // combiner dtype: scalars are fine as f32 for our graphs
        writeln!(out, "{} {{", region_name(*k)).unwrap();
        writeln!(out, "  lhs = {dt}[] parameter(0)").unwrap();
        writeln!(out, "  rhs = {dt}[] parameter(1)").unwrap();
        writeln!(
            out,
            "  ROOT combine = {dt}[] {}(lhs, rhs)",
            match k {
                ReduceKind::Add => "add",
                ReduceKind::Max => "maximum",
                ReduceKind::Min => "minimum",
                ReduceKind::Mul => "multiply",
            }
        )
        .unwrap();
        writeln!(out, "}}").unwrap();
        writeln!(out).unwrap();
    }

    writeln!(out, "ENTRY main {{").unwrap();
    let live = g.live_set();
    let nm = |id: crate::ir::NodeId| format!("v{}", id.0);
    // reduce inits need aux constants; we hoist them with unique names
    let mut aux = 0usize;

    let mut body = String::new();
    for n in &g.nodes {
        if !live[n.id.idx()] {
            continue;
        }
        let shape = n.shape.hlo_text();
        let ops: Vec<String> = n.inputs.iter().map(|&i| nm(i)).collect();
        let meta = {
            let file = g.interner.resolve(n.meta.file);
            if file.is_empty() {
                String::new()
            } else {
                // `stage=` is a Scalify extension (pipeline ownership);
                // omitted for non-pipeline graphs so baseline output stays
                // XLA-parseable
                let stage = match n.meta.stage {
                    Some(s) => format!(" stage={s}"),
                    None => String::new(),
                };
                format!(
                    ", metadata={{op_name=\"{}\" source_file=\"{}\" source_line={}{}}}",
                    g.interner.resolve(n.meta.expr),
                    file,
                    n.meta.line,
                    stage
                )
            }
        };
        let line = match &n.op {
            Op::Parameter { index, .. } => {
                format!("{} = {} parameter({})", nm(n.id), shape, index)
            }
            Op::Constant(c) => {
                let payload = match c {
                    ConstVal::Scalar(v) => fmt_f64(*v),
                    ConstVal::Dense(vs) => {
                        // print flat: our parser (and XLA's, for rank-1)
                        // accepts the brace-flat form
                        if n.shape.rank() == 1 {
                            let items: Vec<String> =
                                vs.iter().map(|v| fmt_f64(*v)).collect();
                            format!("{{{}}}", items.join(", "))
                        } else {
                            nested_const(&n.shape.dims, vs)
                        }
                    }
                };
                format!("{} = {} constant({})", nm(n.id), shape, payload)
            }
            Op::Iota { dim, .. } => {
                format!("{} = {} iota(), iota_dimension={}", nm(n.id), shape, dim)
            }
            Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Max | Op::Min | Op::Pow => {
                format!("{} = {} {}({}, {})", nm(n.id), shape, n.op.name(), ops[0], ops[1])
            }
            Op::Neg
            | Op::Exp
            | Op::Log
            | Op::Tanh
            | Op::Rsqrt
            | Op::Sqrt
            | Op::Abs
            | Op::Logistic
            | Op::Sin
            | Op::Cos
            | Op::Convert { .. }
            | Op::Reshape { .. } => {
                format!("{} = {} {}({})", nm(n.id), shape, n.op.name(), ops[0])
            }
            Op::Compare(kind) => {
                let dir = match kind {
                    CmpKind::Eq => "EQ",
                    CmpKind::Ne => "NE",
                    CmpKind::Lt => "LT",
                    CmpKind::Le => "LE",
                    CmpKind::Gt => "GT",
                    CmpKind::Ge => "GE",
                };
                format!(
                    "{} = {} compare({}, {}), direction={}",
                    nm(n.id),
                    shape,
                    ops[0],
                    ops[1],
                    dir
                )
            }
            Op::Select => {
                format!("{} = {} select({}, {}, {})", nm(n.id), shape, ops[0], ops[1], ops[2])
            }
            Op::Dot { lhs_contract, rhs_contract, lhs_batch, rhs_batch } => {
                let mut attrs = Vec::new();
                if !lhs_batch.is_empty() {
                    attrs.push(format!("lhs_batch_dims={}", usize_list(lhs_batch)));
                }
                attrs.push(format!("lhs_contracting_dims={}", usize_list(lhs_contract)));
                if !rhs_batch.is_empty() {
                    attrs.push(format!("rhs_batch_dims={}", usize_list(rhs_batch)));
                }
                attrs.push(format!("rhs_contracting_dims={}", usize_list(rhs_contract)));
                format!(
                    "{} = {} dot({}, {}), {}",
                    nm(n.id),
                    shape,
                    ops[0],
                    ops[1],
                    attrs.join(", ")
                )
            }
            Op::Transpose { perm } => {
                format!(
                    "{} = {} transpose({}), dimensions={}",
                    nm(n.id),
                    shape,
                    ops[0],
                    usize_list(perm)
                )
            }
            Op::Slice { starts, limits, strides } => {
                let parts: Vec<String> = starts
                    .iter()
                    .zip(limits.iter().zip(strides))
                    .map(|(&s, (&l, &st))| format!("[{s}:{l}:{st}]"))
                    .collect();
                format!("{} = {} slice({}), slice={{{}}}", nm(n.id), shape, ops[0], parts.join(","))
            }
            Op::Concat { dim } => {
                format!(
                    "{} = {} concatenate({}), dimensions={{{}}}",
                    nm(n.id),
                    shape,
                    ops.join(", "),
                    dim
                )
            }
            Op::Broadcast { mapped, .. } => {
                format!(
                    "{} = {} broadcast({}), dimensions={}",
                    nm(n.id),
                    shape,
                    ops[0],
                    usize_list(mapped)
                )
            }
            Op::Reduce { kind, dims } => {
                aux += 1;
                let init = format!("init{aux}");
                let init_dt = n.shape.dtype.hlo_name();
                writeln!(
                    body,
                    "  {} = {}[] constant({})",
                    init,
                    init_dt,
                    reduce_init(*kind)
                )
                .unwrap();
                format!(
                    "{} = {} reduce({}, {}), dimensions={}, to_apply={}",
                    nm(n.id),
                    shape,
                    ops[0],
                    init,
                    usize_list(dims),
                    region_name(*kind)
                )
            }
            Op::AllReduce { kind, groups } => {
                format!(
                    "{} = {} all-reduce({}), replica_groups={}, to_apply={}",
                    nm(n.id),
                    shape,
                    ops[0],
                    groups_text(groups),
                    region_name(*kind)
                )
            }
            Op::AllGather { dim, groups } => {
                format!(
                    "{} = {} all-gather({}), replica_groups={}, dimensions={{{}}}",
                    nm(n.id),
                    shape,
                    ops[0],
                    groups_text(groups),
                    dim
                )
            }
            Op::ReduceScatter { kind, dim, groups } => {
                format!(
                    "{} = {} reduce-scatter({}), replica_groups={}, dimensions={{{}}}, to_apply={}",
                    nm(n.id),
                    shape,
                    ops[0],
                    groups_text(groups),
                    dim,
                    region_name(*kind)
                )
            }
            Op::AllToAll { split_dim, concat_dim, groups } => {
                format!(
                    "{} = {} all-to-all({}), replica_groups={}, dimensions={{{},{}}}",
                    nm(n.id),
                    shape,
                    ops[0],
                    groups_text(groups),
                    split_dim,
                    concat_dim
                )
            }
            Op::Send { channel } => {
                format!("{} = {} send({}), channel_id={}", nm(n.id), shape, ops[0], channel)
            }
            Op::Recv { channel } => {
                format!("{} = {} recv({}), channel_id={}", nm(n.id), shape, ops[0], channel)
            }
            Op::Tuple => {
                format!("{} = {} tuple({})", nm(n.id), shape, ops.join(", "))
            }
            Op::GetTupleElement { index } => {
                format!(
                    "{} = {} get-tuple-element({}), index={}",
                    nm(n.id),
                    shape,
                    ops[0],
                    index
                )
            }
            Op::Custom { name } => {
                format!("{} = {} {}({})", nm(n.id), shape, name, ops.join(", "))
            }
        };
        writeln!(body, "  {}{}", line, meta).unwrap();
    }

    // root tuple over the outputs
    let out_shapes: Vec<String> =
        g.outputs.iter().map(|&o| g.node(o).shape.hlo_text()).collect();
    let out_names: Vec<String> = g.outputs.iter().map(|&o| nm(o)).collect();
    writeln!(
        body,
        "  ROOT result = ({}) tuple({})",
        out_shapes.join(", "),
        out_names.join(", ")
    )
    .unwrap();

    out.push_str(&body);
    writeln!(out, "}}").unwrap();
    out
}

fn nested_const(dims: &[i64], vs: &[f64]) -> String {
    if dims.is_empty() {
        return fmt_f64(vs[0]);
    }
    let chunk = vs.len() / dims[0] as usize;
    let items: Vec<String> = (0..dims[0] as usize)
        .map(|i| nested_const(&dims[1..], &vs[i * chunk..(i + 1) * chunk]))
        .collect();
    format!("{{{}}}", items.join(", "))
}

fn groups_text(groups: &crate::ir::ReplicaGroups) -> String {
    let gs: Vec<String> = groups
        .0
        .iter()
        .map(|g| {
            let ids: Vec<String> = g.iter().map(|c| c.to_string()).collect();
            format!("{{{}}}", ids.join(","))
        })
        .collect();
    format!("{{{}}}", gs.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, ReplicaGroups, Shape};

    #[test]
    fn prints_and_contains_ops() {
        let mut b = GraphBuilder::new("m", 2);
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 4]));
        let t = b.transpose(x, vec![1, 0]);
        let r = b.all_reduce(t, ReduceKind::Add, ReplicaGroups::full(2));
        b.output(r);
        let g = b.finish();
        let text = print_hlo_module(&g);
        assert!(text.contains("transpose"), "{text}");
        assert!(text.contains("all-reduce"), "{text}");
        assert!(text.contains("region_add"), "{text}");
        assert!(text.contains("ROOT result"), "{text}");
    }

    #[test]
    fn nested_const_format() {
        assert_eq!(nested_const(&[2, 2], &[1.0, 2.0, 3.0, 4.0]), "{{1, 2}, {3, 4}}");
        assert_eq!(nested_const(&[3], &[1.5, 2.0, 3.0]), "{1.5, 2, 3}");
    }
}
