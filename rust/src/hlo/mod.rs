//! HLO-text interchange: parse framework-emitted HLO into the IR and print
//! IR graphs back out as HLO text.
//!
//! HLO **text** (never serialized `HloModuleProto`) is the interchange
//! format of this system: jax ≥ 0.5 emits protos with 64-bit instruction
//! ids that the runtime's XLA (xla_extension 0.5.1) rejects, while the text
//! parser reassigns ids and round-trips cleanly. The same text files are
//! what Scalify verifies — the paper operates on the IR graphs that
//! production backends (PyTorch-XLA / NeuronX) dump.
//!
//! The parser covers the HLO subset that jax 0.8 lowers transformer blocks
//! to (see `python/compile/aot.py`) plus the SPMD collectives; anything
//! else is preserved as [`crate::ir::Op::Custom`] so verification can still
//! traverse (and conservatively refuse to equate) unknown ops.

mod parser;
mod printer;

pub use parser::{parse_hlo_module, parse_hlo_file};
pub use printer::print_hlo_module;

#[cfg(test)]
mod roundtrip_tests;
