//! HLO-text → [`crate::ir::Graph`] parser.

use crate::ir::{
    CmpKind, ConstVal, DType, Graph, Meta, NodeId, Op, ReduceKind, ReplicaGroups, Shape,
};
use crate::error::{Result, ResultExt, ScalifyError};
use rustc_hash::FxHashMap;

/// A [`ScalifyError::Parse`] built from a format string.
macro_rules! parse_err {
    ($($arg:tt)*) => { ScalifyError::parse(format!($($arg)*)) };
}

macro_rules! bail {
    ($($arg:tt)*) => { return Err(parse_err!($($arg)*)) };
}

/// Parse an HLO module from a file path.
pub fn parse_hlo_file(path: &std::path::Path, num_cores: u32) -> Result<Graph> {
    let text = std::fs::read_to_string(path)
        .with_ctx(|| format!("reading {}", path.display()))?;
    parse_hlo_module(&text, num_cores)
}

/// Parse HLO text into a graph. `num_cores` declares the SPMD width the
/// module is meant to run at (1 for baseline graphs; the framework records
/// this in its run config, not in the HLO itself).
pub fn parse_hlo_module(text: &str, num_cores: u32) -> Result<Graph> {
    let mut module_name = String::from("module");
    let mut mesh_axes: Vec<u32> = Vec::new();
    // Split into computations: `name {` ... `}` blocks (plus ENTRY marker).
    let mut computations: Vec<(String, bool, Vec<String>)> = Vec::new(); // (name, is_entry, lines)
    let mut current: Option<(String, bool, Vec<String>)> = None;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule") {
            module_name = rest
                .trim()
                .split([',', ' '])
                .next()
                .unwrap_or("module")
                .to_string();
            // optional mesh attribute: `HloModule name, mesh={2,4}`
            if let Some(at) = rest.find("mesh={") {
                let tail = &rest[at + "mesh={".len()..];
                let close =
                    tail.find('}').ok_or_else(|| parse_err!("unbalanced mesh attribute"))?;
                let mut axes = Vec::new();
                for part in tail[..close].split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        continue;
                    }
                    axes.push(
                        part.parse::<u32>()
                            .map_err(|_| parse_err!("bad mesh axis '{part}'"))?,
                    );
                }
                mesh_axes = axes;
            }
            continue;
        }
        if line.ends_with('{') && current.is_none() {
            let header = line.trim_end_matches('{').trim();
            let is_entry = header.starts_with("ENTRY");
            let name = header.trim_start_matches("ENTRY").trim().to_string();
            current = Some((name, is_entry, Vec::new()));
            continue;
        }
        if line == "}" {
            if let Some(c) = current.take() {
                computations.push(c);
            }
            continue;
        }
        if let Some((_, _, lines)) = current.as_mut() {
            lines.push(line.to_string());
        }
    }

    // Classify sub-computations (reduction regions) by their root op.
    let mut region_kind: FxHashMap<String, ReduceKind> = FxHashMap::default();
    for (name, is_entry, lines) in &computations {
        if *is_entry {
            continue;
        }
        for l in lines {
            if let Some(rest) = l.strip_prefix("ROOT ") {
                let kind = if rest.contains("= ") {
                    let opcode = opcode_of(rest);
                    match opcode.as_deref() {
                        Some("add") => Some(ReduceKind::Add),
                        Some("maximum") => Some(ReduceKind::Max),
                        Some("minimum") => Some(ReduceKind::Min),
                        Some("multiply") => Some(ReduceKind::Mul),
                        _ => None,
                    }
                } else {
                    None
                };
                if let Some(k) = kind {
                    region_kind.insert(name.clone(), k);
                }
            }
        }
    }

    let (_, _, entry_lines) = computations
        .iter()
        .find(|(_, is_entry, _)| *is_entry)
        .ok_or_else(|| parse_err!("no ENTRY computation in module"))?;

    // Structural fingerprints of sub-computations, so control-flow ops
    // (`while`, `call`) get congruence-safe identities: two whiles merge in
    // the e-graph only when their bodies are structurally identical.
    let mut region_fp: FxHashMap<String, u64> = FxHashMap::default();
    for (name, is_entry, lines) in &computations {
        if *is_entry {
            continue;
        }
        let fp = fingerprint_computation(lines, &region_kind, &region_fp, num_cores);
        region_fp.insert(name.clone(), fp);
    }

    let mut g = Graph::new(module_name, num_cores);
    if !mesh_axes.is_empty() {
        let total: u32 = mesh_axes.iter().product();
        if total != num_cores {
            bail!(
                "module declares mesh {mesh_axes:?} ({total} cores) but was opened \
                 at {num_cores} cores"
            );
        }
        g.mesh = mesh_axes;
    }
    let mut by_name: FxHashMap<String, NodeId> = FxHashMap::default();
    let mut root: Option<NodeId> = None;

    for line in entry_lines {
        let (name, id, is_root) =
            parse_instruction(&mut g, line, &by_name, &region_kind, &region_fp)
                .with_ctx(|| format!("parsing instruction: {line}"))?;
        by_name.insert(name, id);
        if is_root {
            root = Some(id);
        }
    }

    let root = root.ok_or_else(|| parse_err!("entry computation has no ROOT"))?;
    // Strip a trailing tuple: outputs are its operands.
    match &g.node(root).op {
        Op::Tuple => {
            g.outputs = g.node(root).inputs.clone();
        }
        _ => g.outputs = vec![root],
    }
    g.validate()?;
    Ok(g)
}

/// Structural fingerprint of a sub-computation: parse it as a standalone
/// graph and hash ops/attrs/wiring; falls back to hashing normalized text
/// when the body uses constructs the parser cannot build a graph for.
fn fingerprint_computation(
    lines: &[String],
    region_kind: &FxHashMap<String, ReduceKind>,
    region_fp: &FxHashMap<String, u64>,
    num_cores: u32,
) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    let mut g = Graph::new("region", num_cores);
    let mut by_name: FxHashMap<String, NodeId> = FxHashMap::default();
    let mut ok = true;
    for line in lines {
        match parse_instruction(&mut g, line, &by_name, region_kind, region_fp) {
            Ok((name, id, _)) => {
                by_name.insert(name, id);
            }
            Err(_) => {
                ok = false;
                break;
            }
        }
    }
    if ok {
        for n in &g.nodes {
            match &n.op {
                Op::Parameter { index, .. } => ("param", index).hash(&mut h),
                op => format!("{op:?}").hash(&mut h),
            }
            n.shape.dims.hash(&mut h);
            (n.shape.dtype as u8).hash(&mut h);
            for i in &n.inputs {
                i.0.hash(&mut h);
            }
        }
    } else {
        // normalized text fallback: strip `.N` numbering so identical
        // bodies from different modules hash alike
        for line in lines {
            let norm: String = strip_id_suffixes(line);
            norm.hash(&mut h);
        }
    }
    h.finish()
}

fn strip_id_suffixes(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if c == '.' {
            // skip digit runs following a dot when attached to an identifier
            let mut digits = String::new();
            while let Some(&d) = chars.peek() {
                if d.is_ascii_digit() {
                    digits.push(d);
                    chars.next();
                } else {
                    break;
                }
            }
            if digits.is_empty() {
                out.push(c);
            }
            // else: drop `.N`
        } else {
            out.push(c);
        }
    }
    out
}

fn opcode_of(line: &str) -> Option<String> {
    // `name = type opcode(...)` → opcode
    let rhs = line.split(" = ").nth(1)?;
    // skip the type: either `(tuple, types)` or `dtype[dims]{layout}`
    let rest = if rhs.starts_with('(') {
        let close = matching_paren(rhs, 0)?;
        rhs[close + 1..].trim_start()
    } else {
        let sp = rhs.find(' ')?;
        rhs[sp + 1..].trim_start()
    };
    let end = rest.find('(')?;
    Some(rest[..end].trim().to_string())
}

fn matching_paren(s: &str, open: usize) -> Option<usize> {
    let bytes = s.as_bytes();
    debug_assert_eq!(bytes[open], b'(');
    let mut depth = 0usize;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Parse `f32[2,4]{1,0}` → Shape. Layout suffix ignored.
fn parse_shape(s: &str) -> Result<Shape> {
    let s = s.trim();
    let bracket = s.find('[').ok_or_else(|| parse_err!("no '[' in shape '{s}'"))?;
    let dtype = DType::from_hlo_name(&s[..bracket])
        .ok_or_else(|| parse_err!("unknown dtype '{}'", &s[..bracket]))?;
    let close = s.find(']').ok_or_else(|| parse_err!("no ']' in shape '{s}'"))?;
    let dims_str = &s[bracket + 1..close];
    let dims: Vec<i64> = if dims_str.trim().is_empty() {
        vec![]
    } else {
        dims_str
            .split(',')
            .map(|d| d.trim().parse::<i64>().map_err(|e| parse_err!("bad dim '{d}': {e}")))
            .collect::<Result<_>>()?
    };
    Ok(Shape::new(dtype, dims))
}

/// Parse `{1,0,2}` (or `{}`) into usizes.
fn parse_brace_list(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    if inner.trim().is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|v| v.trim().parse::<usize>().map_err(|e| parse_err!("bad index '{v}': {e}")))
        .collect()
}

/// Parse `{{0,1},{2,3}}` replica groups.
fn parse_replica_groups(s: &str, num_cores: u32) -> Result<ReplicaGroups> {
    let inner = s.trim();
    let inner = inner.strip_prefix('{').and_then(|x| x.strip_suffix('}')).unwrap_or(inner);
    if !inner.contains('{') {
        // `{}` — all cores in one group
        return Ok(ReplicaGroups::full(num_cores));
    }
    let mut groups = Vec::new();
    let mut rest = inner;
    while let Some(open) = rest.find('{') {
        let close =
            rest[open..].find('}').ok_or_else(|| parse_err!("unbalanced replica_groups"))? + open;
        let ids: Vec<u32> = rest[open + 1..close]
            .split(',')
            .filter(|v| !v.trim().is_empty())
            .map(|v| v.trim().parse::<u32>().map_err(|e| parse_err!("bad core id: {e}")))
            .collect::<Result<_>>()?;
        groups.push(ids);
        rest = &rest[close + 1..];
    }
    Ok(ReplicaGroups(groups))
}

/// Extract `key=value` attributes from the trailing attr list. Values may
/// contain nested braces (replica_groups) — we scan brace-aware.
fn parse_attrs(s: &str) -> FxHashMap<String, String> {
    let mut attrs = FxHashMap::default();
    let mut rest = s.trim_start_matches(',').trim();
    while !rest.is_empty() {
        let eq = match rest.find('=') {
            Some(e) => e,
            None => break,
        };
        let key = rest[..eq].trim().to_string();
        let value_str = &rest[eq + 1..];
        let mut depth = 0usize;
        let mut end = value_str.len();
        for (i, b) in value_str.bytes().enumerate() {
            match b {
                b'{' | b'(' | b'[' => depth += 1,
                b'}' | b')' | b']' => depth = depth.saturating_sub(1),
                b',' if depth == 0 => {
                    end = i;
                    break;
                }
                _ => {}
            }
        }
        attrs.insert(key, value_str[..end].trim().to_string());
        rest = value_str[end..].trim_start_matches(',').trim();
    }
    attrs
}

/// Parse constant payload text: `2`, `-inf`, `{1, 2, 3}`, `{{1,2},{3,4}}`.
fn parse_const_payload(s: &str, shape: &Shape) -> Result<ConstVal> {
    let parse_num = |t: &str| -> Result<f64> {
        match t.trim() {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" | "-nan" => Ok(f64::NAN),
            "true" => Ok(1.0),
            "false" => Ok(0.0),
            other => other.parse::<f64>().map_err(|e| parse_err!("bad constant '{other}': {e}")),
        }
    };
    if shape.rank() == 0 {
        return Ok(ConstVal::Scalar(parse_num(s)?));
    }
    let nums: Vec<f64> = s
        .split(|c: char| c == '{' || c == '}' || c == ',')
        .filter(|t| !t.trim().is_empty())
        .map(parse_num)
        .collect::<Result<_>>()?;
    if nums.len() == 1 && shape.elements() > 1 {
        // splat constant: `constant(0)` with non-scalar shape
        return Ok(ConstVal::Dense(vec![nums[0]; shape.elements() as usize]));
    }
    if nums.len() as i64 != shape.elements() {
        bail!("constant payload has {} values, shape {} wants {}", nums.len(), shape, shape.elements());
    }
    Ok(ConstVal::Dense(nums))
}

/// Parse metadata attr: `metadata={op_name="..." source_file="x.py" source_line=42}`.
fn parse_metadata(g: &mut Graph, attr: &str) -> Meta {
    let mut meta = Meta::none();
    let grab = |key: &str| -> Option<String> {
        let pat = format!("{key}=\"");
        let start = attr.find(&pat)? + pat.len();
        let end = attr[start..].find('"')? + start;
        Some(attr[start..end].to_string())
    };
    if let Some(f) = grab("source_file") {
        meta.file = g.interner.intern(&f);
    }
    if let Some(o) = grab("op_name") {
        meta.expr = g.interner.intern(&o);
    }
    if let Some(pos) = attr.find("source_line=") {
        let rest = &attr[pos + "source_line=".len()..];
        let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
        meta.line = rest[..end].parse().unwrap_or(0);
    }
    if let Some(pos) = attr.find("stage=") {
        let rest = &attr[pos + "stage=".len()..];
        let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
        meta.stage = rest[..end].parse().ok();
    }
    meta
}

/// Parse one instruction line. Returns (name, node id, is_root).
fn parse_instruction(
    g: &mut Graph,
    line: &str,
    by_name: &FxHashMap<String, NodeId>,
    region_kind: &FxHashMap<String, ReduceKind>,
    region_fp: &FxHashMap<String, u64>,
) -> Result<(String, NodeId, bool)> {
    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    let eq = line.find(" = ").ok_or_else(|| parse_err!("no '=' in instruction"))?;
    let name = line[..eq].trim().to_string();
    let rhs = line[eq + 3..].trim();

    // type: tuple `( ... )` or plain shape
    let (shape, rest, is_tuple_type) = if rhs.starts_with('(') {
        let close = matching_paren(rhs, 0).ok_or_else(|| parse_err!("unbalanced tuple type"))?;
        // tuple type: parse first element's shape as representative
        let first = rhs[1..close].split(',').next().unwrap_or("f32[]").trim();
        let sh = parse_shape(first).unwrap_or(Shape::scalar(DType::F32));
        (sh, rhs[close + 1..].trim_start(), true)
    } else {
        let sp = rhs.find(' ').ok_or_else(|| parse_err!("no space after type"))?;
        (parse_shape(&rhs[..sp])?, rhs[sp + 1..].trim_start(), false)
    };
    let _ = is_tuple_type;

    let open = rest.find('(').ok_or_else(|| parse_err!("no '(' after opcode"))?;
    let opcode = rest[..open].trim().to_string();
    let close = matching_paren(rest, open).ok_or_else(|| parse_err!("unbalanced operand list"))?;
    let operands_str = &rest[open + 1..close];
    let attrs = parse_attrs(&rest[close + 1..]);

    let meta = attrs
        .get("metadata")
        .map(|m| parse_metadata(g, m))
        .unwrap_or_else(Meta::none);

    let lookup = |op_name: &str| -> Result<NodeId> {
        by_name
            .get(op_name.trim())
            .copied()
            .ok_or_else(|| parse_err!("unknown operand '{}'", op_name.trim()))
    };
    let operands: Vec<&str> = if operands_str.trim().is_empty() {
        vec![]
    } else {
        operands_str.split(',').map(|s| s.trim()).collect()
    };
    // arity-checked operand access: a malformed line like `slice()` is a
    // typed parse error naming the opcode, never an index panic
    let operand = |i: usize| -> Result<NodeId> {
        let o = operands.get(i).ok_or_else(|| {
            parse_err!(
                "{opcode} needs operand #{} but '({operands_str})' names {}",
                i + 1,
                operands.len()
            )
        })?;
        lookup(o)
    };

    let num_cores = g.num_cores;
    let groups = |attrs: &FxHashMap<String, String>| -> Result<ReplicaGroups> {
        match attrs.get("replica_groups") {
            Some(v) => parse_replica_groups(v, num_cores),
            None => Ok(ReplicaGroups::full(num_cores)),
        }
    };

    let (op, inputs): (Op, Vec<NodeId>) = match opcode.as_str() {
        "parameter" => {
            let index: usize = operands_str.trim().parse()?;
            (Op::Parameter { index, name: name.clone() }, vec![])
        }
        "constant" => (Op::Constant(parse_const_payload(operands_str, &shape)?), vec![]),
        "iota" => {
            let dim = attrs
                .get("iota_dimension")
                .ok_or_else(|| parse_err!("iota without iota_dimension"))?
                .parse::<usize>()?;
            (Op::Iota { dim, dims: shape.dims.clone() }, vec![])
        }
        "add" => (Op::Add, vec![operand(0)?, operand(1)?]),
        "subtract" => (Op::Sub, vec![operand(0)?, operand(1)?]),
        "multiply" => (Op::Mul, vec![operand(0)?, operand(1)?]),
        "divide" => (Op::Div, vec![operand(0)?, operand(1)?]),
        "maximum" => (Op::Max, vec![operand(0)?, operand(1)?]),
        "minimum" => (Op::Min, vec![operand(0)?, operand(1)?]),
        "power" => (Op::Pow, vec![operand(0)?, operand(1)?]),
        "negate" => (Op::Neg, vec![operand(0)?]),
        "exponential" => (Op::Exp, vec![operand(0)?]),
        "log" => (Op::Log, vec![operand(0)?]),
        "tanh" => (Op::Tanh, vec![operand(0)?]),
        "rsqrt" => (Op::Rsqrt, vec![operand(0)?]),
        "sqrt" => (Op::Sqrt, vec![operand(0)?]),
        "abs" => (Op::Abs, vec![operand(0)?]),
        "logistic" => (Op::Logistic, vec![operand(0)?]),
        "sine" => (Op::Sin, vec![operand(0)?]),
        "cosine" => (Op::Cos, vec![operand(0)?]),
        "convert" => (Op::Convert { to: shape.dtype }, vec![operand(0)?]),
        "compare" => {
            let kind = match attrs.get("direction").map(|s| s.as_str()) {
                Some("EQ") => CmpKind::Eq,
                Some("NE") => CmpKind::Ne,
                Some("LT") => CmpKind::Lt,
                Some("LE") => CmpKind::Le,
                Some("GT") => CmpKind::Gt,
                Some("GE") => CmpKind::Ge,
                other => bail!("compare with direction {:?}", other),
            };
            (Op::Compare(kind), vec![operand(0)?, operand(1)?])
        }
        "select" => (
            Op::Select,
            vec![operand(0)?, operand(1)?, operand(2)?],
        ),
        "dot" => {
            let get_dims = |key: &str| -> Result<Vec<usize>> {
                attrs.get(key).map(|v| parse_brace_list(v)).unwrap_or(Ok(vec![]))
            };
            (
                Op::Dot {
                    lhs_contract: get_dims("lhs_contracting_dims")?,
                    rhs_contract: get_dims("rhs_contracting_dims")?,
                    lhs_batch: get_dims("lhs_batch_dims")?,
                    rhs_batch: get_dims("rhs_batch_dims")?,
                },
                vec![operand(0)?, operand(1)?],
            )
        }
        "reshape" => (Op::Reshape { dims: shape.dims.clone() }, vec![operand(0)?]),
        "transpose" => {
            let perm = parse_brace_list(
                attrs.get("dimensions").ok_or_else(|| parse_err!("transpose without dims"))?,
            )?;
            (Op::Transpose { perm }, vec![operand(0)?])
        }
        "slice" => {
            let spec = attrs.get("slice").ok_or_else(|| parse_err!("slice without spec"))?;
            let body = spec.trim().trim_matches(|c| c == '{' || c == '}').trim();
            if body.is_empty() {
                bail!("slice spec '{spec}' names no dimensions");
            }
            let mut starts = Vec::new();
            let mut limits = Vec::new();
            let mut strides = Vec::new();
            for part in body.split("],") {
                let p = part.trim().trim_start_matches('[').trim_end_matches(']');
                // every error names the full spec and the bad segment, so
                // a truncated `[0:` or bogus `[a:b]` points at its source
                let field = |v: Option<&str>, what: &str| -> Result<i64> {
                    let v = v
                        .map(str::trim)
                        .filter(|v| !v.is_empty())
                        .ok_or_else(|| {
                            parse_err!("slice spec '{spec}' segment '[{p}]' is missing a {what}")
                        })?;
                    v.parse::<i64>().map_err(|_| {
                        parse_err!(
                            "slice spec '{spec}' segment '[{p}]' has a malformed {what} '{v}'"
                        )
                    })
                };
                let mut it = p.split(':');
                starts.push(field(it.next(), "start")?);
                limits.push(field(it.next(), "limit")?);
                strides.push(match it.next() {
                    None => 1,
                    stride => field(stride, "stride")?,
                });
                if it.next().is_some() {
                    bail!("slice spec '{spec}' segment '[{p}]' has more than start:limit:stride");
                }
            }
            (Op::Slice { starts, limits, strides }, vec![operand(0)?])
        }
        "concatenate" => {
            let dims =
                attrs.get("dimensions").ok_or_else(|| parse_err!("concat without dims"))?;
            let dim = parse_brace_list(dims)?.first().copied().ok_or_else(|| {
                parse_err!("concatenate dimensions '{dims}' name no dimension")
            })?;
            let ins = operands.iter().map(|o| lookup(o)).collect::<Result<Vec<_>>>()?;
            (Op::Concat { dim }, ins)
        }
        "broadcast" => {
            let mapped = parse_brace_list(
                attrs.get("dimensions").ok_or_else(|| parse_err!("broadcast without dims"))?,
            )?;
            (Op::Broadcast { mapped, dims: shape.dims.clone() }, vec![operand(0)?])
        }
        "reduce" => {
            let dims = parse_brace_list(
                attrs.get("dimensions").ok_or_else(|| parse_err!("reduce without dims"))?,
            )?;
            let region = attrs
                .get("to_apply")
                .ok_or_else(|| parse_err!("reduce without to_apply"))?;
            let kind = region_kind
                .get(region.trim())
                .copied()
                .ok_or_else(|| parse_err!("reduce region '{region}' is not a simple combiner"))?;
            // operands = (input, init); init is checked to be the identity
            (Op::Reduce { kind, dims }, vec![operand(0)?])
        }
        "send" | "recv" => {
            let channel: u32 = attrs
                .get("channel_id")
                .map(|v| v.trim().parse())
                .transpose()?
                .unwrap_or(0);
            let op = if opcode == "send" {
                Op::Send { channel }
            } else {
                Op::Recv { channel }
            };
            (op, vec![operand(0)?])
        }
        "all-reduce" => {
            let region = attrs
                .get("to_apply")
                .ok_or_else(|| parse_err!("all-reduce without to_apply"))?;
            let kind = region_kind
                .get(region.trim())
                .copied()
                .ok_or_else(|| parse_err!("all-reduce region '{region}' unknown"))?;
            (Op::AllReduce { kind, groups: groups(&attrs)? }, vec![operand(0)?])
        }
        "all-gather" => {
            let dim = attrs
                .get("dimensions")
                .map(|v| parse_brace_list(v))
                .transpose()?
                .and_then(|v| v.first().copied())
                .or_else(|| {
                    attrs.get("all_gather_dimension").and_then(|v| v.parse::<usize>().ok())
                })
                .ok_or_else(|| parse_err!("all-gather without dimension"))?;
            (Op::AllGather { dim, groups: groups(&attrs)? }, vec![operand(0)?])
        }
        "reduce-scatter" => {
            let region = attrs
                .get("to_apply")
                .ok_or_else(|| parse_err!("reduce-scatter without to_apply"))?;
            let kind = region_kind
                .get(region.trim())
                .copied()
                .ok_or_else(|| parse_err!("reduce-scatter region '{region}' unknown"))?;
            let dim = attrs
                .get("dimensions")
                .map(|v| parse_brace_list(v))
                .transpose()?
                .and_then(|v| v.first().copied())
                .ok_or_else(|| parse_err!("reduce-scatter without dimension"))?;
            (
                Op::ReduceScatter { kind, dim, groups: groups(&attrs)? },
                vec![operand(0)?],
            )
        }
        "all-to-all" => {
            let dims = parse_brace_list(
                attrs.get("dimensions").ok_or_else(|| parse_err!("all-to-all without dims"))?,
            )?;
            let (split_dim, concat_dim) = match dims.len() {
                1 => (dims[0], dims[0]),
                2 => (dims[0], dims[1]),
                _ => bail!("all-to-all with {} dims", dims.len()),
            };
            (
                Op::AllToAll { split_dim, concat_dim, groups: groups(&attrs)? },
                vec![operand(0)?],
            )
        }
        "tuple" => {
            let ins = operands.iter().map(|o| lookup(o)).collect::<Result<Vec<_>>>()?;
            (Op::Tuple, ins)
        }
        "get-tuple-element" => {
            let index = attrs
                .get("index")
                .ok_or_else(|| parse_err!("gte without index"))?
                .parse::<usize>()?;
            (Op::GetTupleElement { index }, vec![operand(0)?])
        }
        other => {
            let ins = operands
                .iter()
                .filter_map(|o| by_name.get(o.trim()).copied())
                .collect::<Vec<_>>();
            // control-flow ops embed their sub-computations' structural
            // fingerprints in the op identity so the e-graph only merges
            // structurally-identical loops/calls
            let mut name = other.to_string();
            for key in ["to_apply", "body", "condition"] {
                if let Some(region) = attrs.get(key) {
                    let fp = region_fp.get(region.trim()).copied().unwrap_or(0);
                    name.push_str(&format!("#{key}={fp:016x}"));
                }
            }
            (Op::Custom { name }, ins)
        }
    };

    let id = g.push(op, inputs, shape, meta);
    Ok((name, id, is_root))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn, entry_computation_layout={(f32[2,2]{1,0}, f32[2,2]{1,0})->(f32[2,2]{1,0})}

ENTRY main.1 {
  Arg_0.1 = f32[2,2]{1,0} parameter(0)
  Arg_1.1 = f32[2,2]{1,0} parameter(1)
  dot.1 = f32[2,2]{1,0} dot(Arg_0.1, Arg_1.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  constant.1 = f32[] constant(2)
  broadcast.1 = f32[2,2]{1,0} broadcast(constant.1), dimensions={}
  add.1 = f32[2,2]{1,0} add(dot.1, broadcast.1)
  ROOT tuple.1 = (f32[2,2]{1,0}) tuple(add.1)
}
"#;

    #[test]
    fn parses_reference_sample() {
        let g = parse_hlo_module(SAMPLE, 1).unwrap();
        assert_eq!(g.len(), 7); // 6 live + stripped root tuple
        assert_eq!(g.outputs.len(), 1);
        assert_eq!(g.node(g.outputs[0]).op, Op::Add);
        assert_eq!(g.parameters().len(), 2);
        assert_eq!(g.name, "jit_fn");
    }

    #[test]
    fn parses_reduce_with_region() {
        let text = r#"
HloModule m

region_0.1 {
  Arg_0.2 = f32[] parameter(0)
  Arg_1.2 = f32[] parameter(1)
  ROOT maximum.1 = f32[] maximum(Arg_0.2, Arg_1.2)
}

ENTRY main {
  p = f32[2,4]{1,0} parameter(0)
  c = f32[] constant(-inf)
  ROOT r = f32[2]{0} reduce(p, c), dimensions={1}, to_apply=region_0.1
}
"#;
        let g = parse_hlo_module(text, 1).unwrap();
        let out = g.node(g.outputs[0]);
        assert_eq!(out.op, Op::Reduce { kind: ReduceKind::Max, dims: vec![1] });
        assert_eq!(out.shape.dims, vec![2]);
    }

    #[test]
    fn parses_collectives() {
        let text = r#"
HloModule m

red.1 {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}

ENTRY main {
  p = f32[4,8]{1,0} parameter(0)
  ar = f32[4,8]{1,0} all-reduce(p), replica_groups={{0,1,2,3}}, to_apply=red.1
  ag = f32[16,8]{1,0} all-gather(ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT t = (f32[16,8]{1,0}) tuple(ag)
}
"#;
        let g = parse_hlo_module(text, 4).unwrap();
        match &g.node(NodeId(1)).op {
            Op::AllReduce { kind, groups } => {
                assert_eq!(*kind, ReduceKind::Add);
                assert_eq!(groups.0, vec![vec![0, 1, 2, 3]]);
            }
            other => panic!("expected all-reduce, got {other:?}"),
        }
        match &g.node(NodeId(2)).op {
            Op::AllGather { dim, .. } => assert_eq!(*dim, 0),
            other => panic!("expected all-gather, got {other:?}"),
        }
    }

    #[test]
    fn parses_metadata() {
        let text = r#"
HloModule m

ENTRY main {
  p = f32[2]{0} parameter(0)
  ROOT e = f32[2]{0} exponential(p), metadata={op_name="jit(f)/exp" source_file="attn.py" source_line=42}
}
"#;
        let g = parse_hlo_module(text, 1).unwrap();
        assert_eq!(g.source_site(g.outputs[0]), "attn.py:42");
    }

    #[test]
    fn parses_slice_and_dense_constant() {
        let text = r#"
HloModule m

ENTRY main {
  c = s32[4]{0} constant({7, 8, 9, 10})
  ROOT s = s32[2]{0} slice(c), slice={[1:3]}
}
"#;
        let g = parse_hlo_module(text, 1).unwrap();
        match &g.node(NodeId(0)).op {
            Op::Constant(ConstVal::Dense(v)) => assert_eq!(v, &vec![7.0, 8.0, 9.0, 10.0]),
            other => panic!("{other:?}"),
        }
        match &g.node(g.outputs[0]).op {
            Op::Slice { starts, limits, strides } => {
                assert_eq!((starts.as_slice(), limits.as_slice(), strides.as_slice()),
                           (&[1][..], &[3][..], &[1][..]));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_op_becomes_custom() {
        let text = r#"
HloModule m

ENTRY main {
  p = f32[2]{0} parameter(0)
  ROOT w = f32[2]{0} weird-op(p), some_attr={1}
}
"#;
        let g = parse_hlo_module(text, 1).unwrap();
        assert_eq!(g.node(g.outputs[0]).op, Op::Custom { name: "weird-op".into() });
    }

    #[test]
    fn real_jax_attention_module_parses() {
        // mirror of the module jax 0.8 lowers for a softmax-attention block
        let text = include_str!("testdata/jax_attn.hlo.txt");
        let g = parse_hlo_module(text, 1).unwrap();
        assert!(g.len() > 20);
        g.validate().unwrap();
        // one bf16 round-trip is present
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::Convert { to: DType::BF16 })));
    }

    /// A minimal one-op module around `line`, for negative-input tests.
    fn module_with(line: &str) -> String {
        format!(
            "HloModule m\n\nENTRY main {{\n  p = f32[4,4]{{1,0}} parameter(0)\n  {line}\n}}\n"
        )
    }

    fn parse_error_of(line: &str) -> ScalifyError {
        parse_hlo_module(&module_with(line), 1)
            .expect_err("malformed instruction must not parse")
    }

    #[test]
    fn truncated_slice_spec_is_a_typed_parse_error() {
        let err = parse_error_of("ROOT s = f32[2,4]{1,0} slice(p), slice={[0:2], [0:}");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("missing a limit"), "{err}");
        assert!(err.message().contains("[0:"), "error must name the bad segment: {err}");
    }

    #[test]
    fn bogus_slice_bound_names_the_spec() {
        let err = parse_error_of("ROOT s = f32[2,4]{1,0} slice(p), slice={[zero:2], [0:4]}");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("malformed start 'zero'"), "{err}");
        assert!(err.message().contains("{[zero:2], [0:4]}"), "{err}");
    }

    #[test]
    fn empty_slice_spec_is_a_typed_parse_error() {
        let err = parse_error_of("ROOT s = f32[2,4]{1,0} slice(p), slice={}");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("names no dimensions"), "{err}");
    }

    #[test]
    fn overlong_slice_segment_is_rejected() {
        let err = parse_error_of("ROOT s = f32[2,4]{1,0} slice(p), slice={[0:2:1:9]}");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("more than start:limit:stride"), "{err}");
    }

    #[test]
    fn transpose_without_dims_is_a_typed_parse_error() {
        let err = parse_error_of("ROOT t = f32[4,4]{1,0} transpose(p)");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("transpose without dims"), "{err}");
    }

    #[test]
    fn bogus_transpose_dim_is_a_typed_parse_error() {
        let err = parse_error_of("ROOT t = f32[4,4]{1,0} transpose(p), dimensions={1,zero}");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("bad index 'zero'"), "{err}");
    }

    #[test]
    fn empty_concat_dims_is_a_typed_parse_error() {
        let err =
            parse_error_of("ROOT c = f32[8,4]{1,0} concatenate(p, p), dimensions={}");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("name no dimension"), "{err}");
    }

    #[test]
    fn missing_operand_is_a_typed_parse_error_not_a_panic() {
        let err = parse_error_of("ROOT s = f32[2,4]{1,0} slice(), slice={[0:2], [0:4]}");
        assert_eq!(err.kind(), "parse", "{err:?}");
        assert!(err.message().contains("slice needs operand #1"), "{err}");
    }
}
