//! Round-trip tests: build → print → parse → re-print / interpret.

use crate::hlo::{parse_hlo_module, print_hlo_module};
use crate::interp::{run_single, run_spmd, Tensor};
use crate::ir::{DType, GraphBuilder, ReduceKind, ReplicaGroups, Shape};
use crate::util::Prng;

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

#[test]
fn roundtrip_preserves_structure() {
    let mut b = GraphBuilder::new("rt", 1);
    b.at("model.py", 7).in_func("mlp");
    let x = b.parameter("x", f32s(&[4, 8]));
    let w = b.parameter("w", f32s(&[8, 8]));
    let h = b.matmul(x, w);
    let a = b.tanh(h);
    let m = b.reduce(a, ReduceKind::Max, vec![1]);
    let mb = b.broadcast(m, vec![4, 8], vec![0]);
    let y = b.sub(a, mb);
    b.output(y);
    let g = b.finish();

    let text = print_hlo_module(&g);
    let g2 = parse_hlo_module(&text, 1).unwrap();
    assert_eq!(g2.len(), g.live_set().iter().filter(|&&l| l).count() + 2); // + init const + tuple
    // metadata survives
    assert_eq!(g2.source_site(g2.outputs[0]), "model.py:7");

    // second round-trip is a fixpoint on structure
    let text2 = print_hlo_module(&g2);
    let g3 = parse_hlo_module(&text2, 1).unwrap();
    assert_eq!(g3.len(), g2.len());
}

/// Golden-fixture round trip: parse the checked-in HLO, re-print, re-parse
/// — the printer/parser pair must reach a byte-stable fixpoint, preserve
/// the pipeline boundary ops + stage metadata, and stay numerically
/// faithful under the SPMD interpreter.
fn assert_fixture_roundtrips(text: &str, cores: u32, expect_ops: &[&str]) {
    let g1 = parse_hlo_module(text, cores).unwrap();
    g1.validate().unwrap();
    for op in expect_ops {
        assert!(
            g1.nodes.iter().any(|n| n.op.name() == *op),
            "fixture lost op '{op}'"
        );
    }
    let printed = print_hlo_module(&g1);
    let g2 = parse_hlo_module(&printed, cores).unwrap();
    // printer fixpoint: a second print is byte-identical (the snapshot
    // property, without hand-maintaining printer bytes in the fixture)
    assert_eq!(printed, print_hlo_module(&g2), "printer is not a fixpoint");

    // numerics survive the round trip
    let mut p = Prng::new(0xF1);
    let mk_inputs = |g: &crate::ir::Graph, p: &mut Prng| -> Vec<Vec<Tensor>> {
        let one: Vec<Tensor> = g
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(g.node(pid).shape.clone(), p))
            .collect();
        (0..cores as usize).map(|_| one.clone()).collect()
    };
    let ins = mk_inputs(&g1, &mut p);
    let out1 = run_spmd(&g1, &ins).unwrap();
    let out2 = run_spmd(&g2, &ins).unwrap();
    for core in 0..cores as usize {
        for (a, b) in out1[core].iter().zip(&out2[core]) {
            assert!(a.max_abs_diff(b) < 1e-9, "core {core} drifted across the round trip");
        }
    }
}

#[test]
fn pipeline_fixture_roundtrips_with_stage_metadata() {
    let text = include_str!("testdata/pipeline_pp2.hlo.txt");
    assert_fixture_roundtrips(text, 2, &["send", "recv"]);
    let g = parse_hlo_module(text, 2).unwrap();
    // stage annotations survive parsing and printing
    let stages: Vec<Option<u32>> = g.nodes.iter().map(|n| n.meta.stage).collect();
    assert!(stages.contains(&Some(0)) && stages.contains(&Some(1)));
    let reprinted = print_hlo_module(&g);
    assert!(reprinted.contains("stage=0") && reprinted.contains("stage=1"), "{reprinted}");
    assert!(reprinted.contains("channel_id=0"), "{reprinted}");
}

#[test]
fn zero_fixture_roundtrips_with_sharded_state_collectives() {
    let text = include_str!("testdata/zero1_dp2.hlo.txt");
    assert_fixture_roundtrips(text, 2, &["reduce-scatter", "all-gather", "dot"]);
}

#[test]
fn mesh_fixture_roundtrips_with_subgroup_collectives() {
    // subgroup `replica_groups={{0,1},{2,3}}` syntax + the `mesh={2,2}`
    // module attribute: printer → parser → printer golden fixpoint
    let text = include_str!("testdata/mesh_dp2tp2.hlo.txt");
    assert_fixture_roundtrips(text, 4, &["all-reduce", "reduce-scatter", "all-gather"]);
    let g = parse_hlo_module(text, 4).unwrap();
    assert_eq!(g.mesh, vec![2, 2], "mesh attribute must survive parsing");
    let tp_groups: Vec<Vec<u32>> = vec![vec![0, 1], vec![2, 3]];
    let dp_groups: Vec<Vec<u32>> = vec![vec![0, 2], vec![1, 3]];
    assert!(g.nodes.iter().any(|n| matches!(
        &n.op,
        crate::ir::Op::AllReduce { groups, .. } if groups.0 == tp_groups
    )));
    assert!(g.nodes.iter().any(|n| matches!(
        &n.op,
        crate::ir::Op::ReduceScatter { groups, .. } if groups.0 == dp_groups
    )));
    let reprinted = print_hlo_module(&g);
    assert!(reprinted.contains("mesh={2,2}"), "{reprinted}");
    assert!(reprinted.contains("replica_groups={{0,1},{2,3}}"), "{reprinted}");
    assert!(reprinted.contains("replica_groups={{0,2},{1,3}}"), "{reprinted}");
}

#[test]
fn engine_mesh_graph_roundtrips_through_hlo_text() {
    use crate::modelgen::{dpstep_pair, Parallelism, TrainStepConfig};
    let pair = dpstep_pair(
        &TrainStepConfig::tiny(),
        Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 },
    );
    let text = print_hlo_module(&pair.dist);
    assert!(text.contains("mesh={2,2}"), "{text}");
    let back = parse_hlo_module(&text, 4).unwrap();
    back.validate().unwrap();
    assert_eq!(back.mesh, vec![2, 2]);
    // subgroup collectives survive byte-exactly
    let collect = |g: &crate::ir::Graph| -> Vec<String> {
        g.nodes
            .iter()
            .filter(|n| n.op.is_collective())
            .map(|n| format!("{:?}", n.op))
            .collect()
    };
    assert_eq!(collect(&pair.dist), collect(&back));
}

#[test]
fn mesh_mismatch_is_a_parse_error() {
    let text = "HloModule m, mesh={2,2}\n\nENTRY main {\n  v0 = f32[2]{0} parameter(0)\n  ROOT r = (f32[2]) tuple(v0)\n}\n";
    // opened at 2 cores but the mesh covers 4
    assert!(parse_hlo_module(text, 2).is_err());
    assert!(parse_hlo_module(text, 4).is_ok());
}

#[test]
fn engine_pipeline_graph_roundtrips_through_hlo_text() {
    use crate::modelgen::{llama_pair, LlamaConfig, Parallelism};
    let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Pipeline { pp: 2 });
    let text = print_hlo_module(&pair.dist);
    assert!(text.contains("send(") && text.contains("recv("), "{text}");
    let back = parse_hlo_module(&text, 2).unwrap();
    back.validate().unwrap();
    // boundary ops and stage tags survive
    assert!(back.nodes.iter().any(|n| n.op.name() == "send"));
    assert!(back.nodes.iter().any(|n| n.meta.stage == Some(1)));
}

#[test]
fn roundtrip_preserves_numerics() {
    let mut b = GraphBuilder::new("rt", 1);
    let x = b.parameter("x", f32s(&[3, 5]));
    let w = b.parameter("w", f32s(&[5, 2]));
    let h = b.matmul(x, w);
    let e = b.exp(h);
    let s = b.reduce(e, ReduceKind::Add, vec![1]);
    b.output(s);
    let g = b.finish();

    let mut p = Prng::new(3);
    let xv = Tensor::random(f32s(&[3, 5]), &mut p);
    let wv = Tensor::random(f32s(&[5, 2]), &mut p);
    let before = run_single(&g, &[xv.clone(), wv.clone()]).unwrap();

    let g2 = parse_hlo_module(&print_hlo_module(&g), 1).unwrap();
    let after = run_single(&g2, &[xv, wv]).unwrap();
    assert!(before[0].max_abs_diff(&after[0]) < 1e-9);
}

#[test]
fn roundtrip_spmd_collectives() {
    let mut b = GraphBuilder::new("rt", 4);
    let x = b.parameter("x", f32s(&[2, 4]));
    let ar = b.all_reduce(x, ReduceKind::Add, ReplicaGroups::full(4));
    let rs = b.reduce_scatter(ar, ReduceKind::Max, 1, ReplicaGroups::full(4));
    let ag = b.all_gather(rs, 1, ReplicaGroups::full(4));
    let a2a = b.all_to_all(ag, 0, 1, ReplicaGroups::split(4, 2));
    b.output(a2a);
    let g = b.finish();

    let g2 = parse_hlo_module(&print_hlo_module(&g), 4).unwrap();
    let mut p = Prng::new(17);
    let ins: Vec<Vec<Tensor>> =
        (0..4).map(|_| vec![Tensor::random(f32s(&[2, 4]), &mut p)]).collect();
    let before = run_spmd(&g, &ins).unwrap();
    let after = run_spmd(&g2, &ins).unwrap();
    for c in 0..4 {
        assert!(before[c][0].max_abs_diff(&after[c][0]) < 1e-9, "core {c}");
    }
}

#[test]
fn parse_real_jax_module_and_interpret() {
    // The checked-in jax artifact: attention block lowered by jax 0.8.
    let text = include_str!("testdata/jax_attn.hlo.txt");
    let g = parse_hlo_module(text, 1).unwrap();
    let mut p = Prng::new(23);
    let inputs: Vec<Tensor> = g
        .parameters()
        .iter()
        .map(|&pid| Tensor::random(g.node(pid).shape.clone(), &mut p))
        .collect();
    let out = run_single(&g, &inputs).unwrap();
    assert_eq!(out[0].shape.dims, vec![4, 2, 8]);
    // attention rows passed through softmax: all finite
    assert!(out[0].data.iter().all(|v| v.is_finite()));
}
