//! Tensor shapes: dtype + dimension vector, HLO-text formatting.

use super::DType;
use std::fmt;

/// A tensor shape: element type plus dimensions.
///
/// Scalars are rank-0 (`dims` empty). Dimensions are `i64` to match HLO;
/// all shapes in this system are static (dynamic shapes are out of the
/// paper's scope — NeuronX inference graphs are fully static).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    /// Element type.
    pub dtype: DType,
    /// Dimension sizes, outermost first.
    pub dims: Vec<i64>,
}

impl Shape {
    /// Construct a shape.
    pub fn new(dtype: DType, dims: Vec<i64>) -> Self {
        Shape { dtype, dims }
    }

    /// Rank-0 scalar of `dtype`.
    pub fn scalar(dtype: DType) -> Self {
        Shape { dtype, dims: vec![] }
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total element count.
    pub fn elements(&self) -> i64 {
        self.dims.iter().product()
    }

    /// Total byte size.
    pub fn bytes(&self) -> usize {
        self.elements() as usize * self.dtype.size_bytes()
    }

    /// Same dims, different dtype.
    pub fn with_dtype(&self, dtype: DType) -> Shape {
        Shape { dtype, dims: self.dims.clone() }
    }

    /// Same dtype, different dims.
    pub fn with_dims(&self, dims: Vec<i64>) -> Shape {
        Shape { dtype: self.dtype, dims }
    }

    /// HLO-text spelling, e.g. `f32[4,64,4096]` / `bf16[]`.
    pub fn hlo_text(&self) -> String {
        let dims: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        format!("{}[{}]", self.dtype.hlo_name(), dims.join(","))
    }

    /// Row-major strides (in elements) of this shape.
    pub fn strides(&self) -> Vec<i64> {
        let mut strides = vec![1i64; self.dims.len()];
        for i in (0..self.dims.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.dims[i + 1];
        }
        strides
    }

    /// Convert a flat row-major index into per-dimension coordinates.
    pub fn unflatten_index(&self, mut flat: i64) -> Vec<i64> {
        let strides = self.strides();
        let mut coords = vec![0i64; self.dims.len()];
        for (i, s) in strides.iter().enumerate() {
            coords[i] = flat / s;
            flat %= s;
        }
        coords
    }

    /// Convert coordinates back to a flat row-major index.
    pub fn flatten_index(&self, coords: &[i64]) -> i64 {
        debug_assert_eq!(coords.len(), self.dims.len());
        self.strides().iter().zip(coords).map(|(s, c)| s * c).sum()
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hlo_text())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(dims: &[i64]) -> Shape {
        Shape::new(DType::F32, dims.to_vec())
    }

    #[test]
    fn display_matches_hlo() {
        assert_eq!(s(&[4, 64, 4096]).to_string(), "f32[4,64,4096]");
        assert_eq!(Shape::scalar(DType::BF16).to_string(), "bf16[]");
    }

    #[test]
    fn elements_and_bytes() {
        assert_eq!(s(&[4, 8]).elements(), 32);
        assert_eq!(s(&[4, 8]).bytes(), 128);
        assert_eq!(Shape::scalar(DType::F32).elements(), 1);
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(s(&[2, 3, 4]).strides(), vec![12, 4, 1]);
        assert_eq!(s(&[5]).strides(), vec![1]);
        assert!(Shape::scalar(DType::F32).strides().is_empty());
    }

    #[test]
    fn index_roundtrip() {
        let sh = s(&[3, 4, 5]);
        for flat in 0..sh.elements() {
            let coords = sh.unflatten_index(flat);
            assert_eq!(sh.flatten_index(&coords), flat);
            for (c, d) in coords.iter().zip(&sh.dims) {
                assert!(c < d);
            }
        }
    }
}
