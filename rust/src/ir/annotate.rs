//! Cross-graph input annotations (§5.2.1).
//!
//! Production frameworks do not record how the distributed graph's inputs
//! relate to the baseline graph's inputs; Scalify instruments the compiler
//! to log sharding/replication during IR generation. We model the result
//! of that instrumentation as [`Annotation`]s carried by a graph *pair*:
//! each annotation ties a baseline parameter to its distributed
//! counterpart and states the placement relation.

use super::NodeId;

/// How a distributed input tensor relates to a baseline input tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum InputRelation {
    /// Distributed parameter is shard `r` (its core index) of the baseline
    /// tensor along `dim`, split evenly across `parts` cores:
    /// `shard_along(self, tensor, dim)` in the paper's notation.
    ShardAlong {
        /// Split dimension.
        dim: usize,
        /// Number of shards (= cores in the group).
        parts: u32,
        /// Mesh axis the shard spans (0 for flat 1-axis meshes). A tensor
        /// sharded along the `tp` axis of a `[dp, tp]` mesh has shard
        /// index `digit_tp(core)`, not the raw core id.
        axis: usize,
    },
    /// Distributed parameter is a full replica of the baseline tensor on
    /// every core.
    Replicated,
    /// Auxiliary tensor carrying device metadata (e.g.
    /// `torch.arange(tp_degree)` used for expert routing). Not derived
    /// automatically — manually specified, as in the paper.
    DeviceIds,
}

/// One registered input relation between the graph pair.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Annotation {
    /// Parameter node in the baseline graph (None for aux-only tensors).
    pub baseline: Option<NodeId>,
    /// Parameter node in the distributed graph.
    pub distributed: NodeId,
    /// The relation.
    pub relation: InputRelation,
}

impl Annotation {
    /// Shorthand: distributed param `d` is baseline param `b` sharded
    /// along `dim` across `parts` cores (flat mesh / axis 0).
    pub fn shard(b: NodeId, d: NodeId, dim: usize, parts: u32) -> Annotation {
        Annotation::shard_on(b, d, dim, parts, 0)
    }

    /// Like [`Annotation::shard`], but naming the mesh axis the shard
    /// spans (`parts` must equal that axis's size).
    pub fn shard_on(b: NodeId, d: NodeId, dim: usize, parts: u32, axis: usize) -> Annotation {
        Annotation {
            baseline: Some(b),
            distributed: d,
            relation: InputRelation::ShardAlong { dim, parts, axis },
        }
    }

    /// Shorthand: distributed param `d` replicates baseline param `b`.
    pub fn replicated(b: NodeId, d: NodeId) -> Annotation {
        Annotation { baseline: Some(b), distributed: d, relation: InputRelation::Replicated }
    }

    /// Shorthand: distributed param `d` carries device ids.
    pub fn device_ids(d: NodeId) -> Annotation {
        Annotation { baseline: None, distributed: d, relation: InputRelation::DeviceIds }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let a = Annotation::shard(NodeId(0), NodeId(1), 1, 32);
        assert_eq!(a.relation, InputRelation::ShardAlong { dim: 1, parts: 32, axis: 0 });
        let m = Annotation::shard_on(NodeId(0), NodeId(1), 0, 2, 1);
        assert_eq!(m.relation, InputRelation::ShardAlong { dim: 0, parts: 2, axis: 1 });
        let r = Annotation::replicated(NodeId(2), NodeId(3));
        assert_eq!(r.relation, InputRelation::Replicated);
        let d = Annotation::device_ids(NodeId(4));
        assert!(d.baseline.is_none());
    }
}
