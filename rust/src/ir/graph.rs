//! Graph arena: nodes in def-before-use order plus source metadata.

use super::{Op, Shape};
use crate::util::{Interner, Sym};
use crate::error::Result;
use rustc_hash::FxHashMap;

/// Structural-validation failure (a [`crate::error::ScalifyError::ModelSpec`]).
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(crate::error::ScalifyError::model_spec(format!($($arg)*)));
        }
    };
}

macro_rules! bail {
    ($($arg:tt)*) => {
        return Err(crate::error::ScalifyError::model_spec(format!($($arg)*)))
    };
}

/// Index of a node within its [`Graph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    /// Usize view for indexing.
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Source metadata attached to each node (§5.3 of the paper): Scalify's
/// compiler instrumentation records the tensor-program site each IR node
/// was generated from, and bug localization reports it back.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Meta {
    /// Source file (interned), e.g. `attention.py`.
    pub file: Sym,
    /// Source line.
    pub line: u32,
    /// Expression text (interned), e.g. `hlo.exp(...)`.
    pub expr: Sym,
    /// Enclosing framework function (interned), e.g. `flash_decoding`.
    pub func: Sym,
    /// Neural-network layer index this node belongs to (layer-boundary
    /// partitioning cuts along this).
    pub layer: Option<u32>,
    /// Pipeline stage that owns this node (None outside pipeline
    /// parallelism or for tensors replicated across stages). Recorded by
    /// the transform engine's stage splitter; surfaced per layer in
    /// [`crate::verifier::LayerReport::stage`].
    pub stage: Option<u32>,
}

impl Meta {
    /// Metadata with everything empty (parser fills what it can).
    pub fn none() -> Meta {
        Meta {
            file: Sym::EMPTY,
            line: 0,
            expr: Sym::EMPTY,
            func: Sym::EMPTY,
            layer: None,
            stage: None,
        }
    }
}

/// One IR node: operator, operand edges, output shape, metadata.
#[derive(Clone, Debug)]
pub struct Node {
    /// Arena id.
    pub id: NodeId,
    /// Operator kind + attributes.
    pub op: Op,
    /// Operand node ids (all `<` this node's id).
    pub inputs: Vec<NodeId>,
    /// Per-core output shape (SPMD graphs store the local shard shape).
    pub shape: Shape,
    /// Source site.
    pub meta: Meta,
}

/// A computational graph.
///
/// Baseline graphs have `num_cores == 1`; distributed graphs are SPMD over
/// `num_cores` cores — every node describes the *per-core* computation and
/// collectives communicate across cores.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Human-readable name (module name in HLO text).
    pub name: String,
    /// Node arena in def-before-use order.
    pub nodes: Vec<Node>,
    /// Output node ids (roots).
    pub outputs: Vec<NodeId>,
    /// SPMD width (1 = single device).
    pub num_cores: u32,
    /// Logical mesh axis sizes over the cores (slowest first; product must
    /// equal `num_cores`). Empty = the classic flat 1-axis view. Set by
    /// the transform engine for mesh plans and round-tripped through HLO
    /// text (`mesh={dp,tp}` module attribute) so the verifier can map
    /// subgroup `replica_groups` back onto axes.
    pub mesh: Vec<u32>,
    /// Interner for `Meta` strings.
    pub interner: Interner,
}

impl Graph {
    /// Empty graph.
    pub fn new(name: impl Into<String>, num_cores: u32) -> Graph {
        Graph {
            name: name.into(),
            nodes: Vec::new(),
            outputs: Vec::new(),
            num_cores,
            mesh: Vec::new(),
            interner: Interner::new(),
        }
    }

    /// The logical mesh view of this graph's cores: the declared axes, or
    /// the flat 1-axis mesh when none were declared.
    pub fn mesh_view(&self) -> super::Mesh {
        if self.mesh.is_empty() {
            super::Mesh::flat(self.num_cores)
        } else {
            super::Mesh::new(self.mesh.clone())
        }
    }

    /// Append a node (callers must pass operands that already exist).
    pub fn push(&mut self, op: Op, inputs: Vec<NodeId>, shape: Shape, meta: Meta) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        for &inp in &inputs {
            debug_assert!(inp.0 < id.0, "def-before-use violated");
        }
        self.nodes.push(Node { id, op, inputs, shape, meta });
        id
    }

    /// Node by id.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.idx()]
    }

    /// Mutable node by id (used by the bug injector).
    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.idx()]
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Parameters in index order.
    pub fn parameters(&self) -> Vec<NodeId> {
        let mut params: Vec<(usize, NodeId)> = self
            .nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Parameter { index, .. } => Some((*index, n.id)),
                _ => None,
            })
            .collect();
        params.sort_unstable();
        params.into_iter().map(|(_, id)| id).collect()
    }

    /// use-lists: for each node, the ids of nodes consuming it.
    pub fn uses(&self) -> Vec<Vec<NodeId>> {
        let mut uses = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &inp in &n.inputs {
                uses[inp.idx()].push(n.id);
            }
        }
        uses
    }

    /// Re-intern `meta` (owned by `src`'s interner) into this graph.
    /// The single place graph-rebuilding passes (layer slicing, the
    /// transform engine, bug-injection surgery) copy metadata through, so
    /// a new [`Meta`] field is threaded in one spot.
    pub fn import_meta(&mut self, src: &Graph, meta: &Meta) -> Meta {
        Meta {
            file: self.interner.intern(src.interner.resolve(meta.file)),
            line: meta.line,
            expr: self.interner.intern(src.interner.resolve(meta.expr)),
            func: self.interner.intern(src.interner.resolve(meta.func)),
            layer: meta.layer,
            stage: meta.stage,
        }
    }

    /// Source site of a node as `file:line` (empty if unknown).
    pub fn source_site(&self, id: NodeId) -> String {
        let m = &self.node(id).meta;
        let file = self.interner.resolve(m.file);
        if file.is_empty() {
            String::new()
        } else {
            format!("{}:{}", file, m.line)
        }
    }

    /// Count of nodes per layer (None-layer nodes under `u32::MAX`).
    pub fn layer_histogram(&self) -> FxHashMap<u32, usize> {
        let mut h = FxHashMap::default();
        for n in &self.nodes {
            *h.entry(n.meta.layer.unwrap_or(u32::MAX)).or_insert(0) += 1;
        }
        h
    }

    /// Structural validation: def-before-use, arity, in-range attributes,
    /// collective groups consistent with `num_cores`, outputs exist.
    pub fn validate(&self) -> Result<()> {
        for n in &self.nodes {
            for &inp in &n.inputs {
                ensure!(
                    inp.0 < n.id.0,
                    "node {} ({}) uses forward reference {}",
                    n.id.0,
                    n.op.name(),
                    inp.0
                );
            }
            let arity_ok = match &n.op {
                Op::Parameter { .. } | Op::Constant(_) | Op::Iota { .. } => n.inputs.is_empty(),
                Op::Add
                | Op::Sub
                | Op::Mul
                | Op::Div
                | Op::Max
                | Op::Min
                | Op::Pow
                | Op::Dot { .. }
                | Op::Compare(_) => n.inputs.len() == 2,
                Op::Select => n.inputs.len() == 3,
                Op::Neg
                | Op::Exp
                | Op::Log
                | Op::Tanh
                | Op::Rsqrt
                | Op::Sqrt
                | Op::Abs
                | Op::Logistic
                | Op::Sin
                | Op::Cos
                | Op::Convert { .. }
                | Op::Reshape { .. }
                | Op::Transpose { .. }
                | Op::Slice { .. }
                | Op::Broadcast { .. }
                | Op::Reduce { .. }
                | Op::AllReduce { .. }
                | Op::AllGather { .. }
                | Op::ReduceScatter { .. }
                | Op::AllToAll { .. }
                | Op::Send { .. }
                | Op::Recv { .. }
                | Op::GetTupleElement { .. } => n.inputs.len() == 1,
                Op::Concat { .. } | Op::Tuple => !n.inputs.is_empty(),
                Op::Custom { .. } => true,
            };
            ensure!(arity_ok, "node {} ({}) has arity {}", n.id.0, n.op.name(), n.inputs.len());

            match &n.op {
                Op::Transpose { perm } => {
                    let rank = self.node(n.inputs[0]).shape.rank();
                    ensure!(perm.len() == rank, "transpose perm rank mismatch at {}", n.id.0);
                    let mut seen = vec![false; rank];
                    for &p in perm {
                        ensure!(p < rank && !seen[p], "bad transpose perm at {}", n.id.0);
                        seen[p] = true;
                    }
                }
                Op::Reshape { dims } => {
                    let in_el = self.node(n.inputs[0]).shape.elements();
                    ensure!(
                        dims == &n.shape.dims,
                        "reshape dims attr disagrees with node shape at {}",
                        n.id.0
                    );
                    ensure!(
                        in_el == n.shape.elements(),
                        "reshape changes element count at {} ({} -> {})",
                        n.id.0,
                        in_el,
                        n.shape.elements()
                    );
                }
                Op::Concat { dim } => {
                    ensure!(*dim < n.shape.rank(), "concat dim out of range at {}", n.id.0);
                }
                Op::Recv { channel } => {
                    let src = self.node(n.inputs[0]);
                    ensure!(
                        matches!(&src.op, Op::Send { channel: c } if c == channel),
                        "recv at {} (channel {}) does not read a matching send",
                        n.id.0,
                        channel
                    );
                }
                Op::AllReduce { groups, .. }
                | Op::AllGather { groups, .. }
                | Op::ReduceScatter { groups, .. }
                | Op::AllToAll { groups, .. } => {
                    // full well-formedness, not just in-bounds: overlapping
                    // or non-covering groups would *silently* mis-evaluate
                    // in the lockstep interpreter and mis-verify in the
                    // relation rules, so they are rejected up front
                    if let Err(why) = groups.check_partition(self.num_cores) {
                        let site = self.source_site(n.id);
                        let at = if site.is_empty() {
                            format!("node {}", n.id.0)
                        } else {
                            format!("node {} ({site})", n.id.0)
                        };
                        bail!("{} at {at}: {why}", n.op.name());
                    }
                }
                _ => {}
            }
        }
        for &out in &self.outputs {
            if out.idx() >= self.nodes.len() {
                bail!("output {} out of range", out.0);
            }
        }
        ensure!(!self.outputs.is_empty(), "graph has no outputs");
        if !self.mesh.is_empty() {
            // AxesMask is a u8 bitmask: more than 8 axes would silently
            // truncate masks instead of erroring, so cap the rank here
            ensure!(
                self.mesh.len() <= 8,
                "mesh declares {} axes (at most 8 supported)",
                self.mesh.len()
            );
            ensure!(
                self.mesh.iter().all(|&a| a >= 1),
                "mesh axes must all be >= 1 (got {:?})",
                self.mesh
            );
            let total: u32 = self.mesh.iter().product();
            ensure!(
                total == self.num_cores,
                "mesh {:?} covers {} cores but the graph declares {}",
                self.mesh,
                total,
                self.num_cores
            );
        }
        Ok(())
    }

    /// Nodes reachable (backwards) from the outputs.
    pub fn live_set(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<NodeId> = self.outputs.clone();
        while let Some(id) = stack.pop() {
            if live[id.idx()] {
                continue;
            }
            live[id.idx()] = true;
            stack.extend(self.node(id).inputs.iter().copied());
        }
        live
    }

    /// Short multi-line summary for debugging.
    pub fn summary(&self) -> String {
        format!(
            "graph '{}': {} nodes, {} outputs, {} cores, {} params",
            self.name,
            self.nodes.len(),
            self.outputs.len(),
            self.num_cores,
            self.parameters().len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder};

    #[test]
    fn build_and_validate_tiny_graph() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", Shape::new(DType::F32, vec![2, 3]));
        let y = b.parameter("y", Shape::new(DType::F32, vec![2, 3]));
        let z = b.add(x, y);
        b.output(z);
        let g = b.finish();
        g.validate().unwrap();
        assert_eq!(g.len(), 3);
        assert_eq!(g.parameters().len(), 2);
        assert_eq!(g.uses()[x.idx()], vec![z]);
    }

    #[test]
    fn validate_rejects_bad_reshape() {
        let mut g = Graph::new("bad", 1);
        let x = g.push(
            Op::Parameter { index: 0, name: "x".into() },
            vec![],
            Shape::new(DType::F32, vec![4]),
            Meta::none(),
        );
        let r = g.push(Op::Reshape { dims: vec![5] }, vec![x], Shape::new(DType::F32, vec![5]), Meta::none());
        g.outputs.push(r);
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_core_out_of_range() {
        use crate::ir::{ReduceKind, ReplicaGroups};
        let mut g = Graph::new("bad", 2);
        let x = g.push(
            Op::Parameter { index: 0, name: "x".into() },
            vec![],
            Shape::new(DType::F32, vec![4]),
            Meta::none(),
        );
        let ar = g.push(
            Op::AllReduce { kind: ReduceKind::Add, groups: ReplicaGroups::full(4) },
            vec![x],
            Shape::new(DType::F32, vec![4]),
            Meta::none(),
        );
        g.outputs.push(ar);
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_malformed_replica_groups() {
        use crate::ir::{ReduceKind, ReplicaGroups};
        let build = |groups: ReplicaGroups| {
            let mut g = Graph::new("bad", 4);
            let x = g.push(
                Op::Parameter { index: 0, name: "x".into() },
                vec![],
                Shape::new(DType::F32, vec![4]),
                Meta::none(),
            );
            let ar = g.push(
                Op::AllReduce { kind: ReduceKind::Add, groups },
                vec![x],
                Shape::new(DType::F32, vec![4]),
                Meta::none(),
            );
            g.outputs.push(ar);
            g
        };
        // overlapping groups
        let err = build(ReplicaGroups(vec![vec![0, 1], vec![1, 2, 3]]))
            .validate()
            .unwrap_err();
        assert!(err.message().contains("more than one replica group"), "{err}");
        // non-covering groups
        let err = build(ReplicaGroups(vec![vec![0, 1], vec![2]])).validate().unwrap_err();
        assert!(err.message().contains("not covered"), "{err}");
        // well-formed subgroups pass
        build(ReplicaGroups(vec![vec![0, 2], vec![1, 3]])).validate().unwrap();
    }

    #[test]
    fn validate_checks_mesh_consistency() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.parameter("x", Shape::new(DType::F32, vec![2]));
        let y = b.neg(x);
        b.output(y);
        let mut g = b.finish();
        g.mesh = vec![2, 2];
        g.validate().unwrap();
        assert_eq!(g.mesh_view().axes, vec![2, 2]);
        g.mesh = vec![3, 2];
        assert!(g.validate().is_err());
        g.mesh = Vec::new();
        assert_eq!(g.mesh_view().axes, vec![4]);
    }

    #[test]
    fn live_set_skips_dead_nodes() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", Shape::new(DType::F32, vec![2]));
        let _dead = b.exp(x);
        let out = b.neg(x);
        b.output(out);
        let g = b.finish();
        let live = g.live_set();
        assert!(live[x.idx()]);
        assert!(live[out.idx()]);
        assert_eq!(live.iter().filter(|&&l| l).count(), 2);
    }
}
