//! Element types. The subset covers what transformer inference graphs use.

use std::fmt;

/// Tensor element type.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    /// IEEE binary32.
    F32,
    /// IEEE binary16.
    F16,
    /// bfloat16 (truncated binary32) — the default transformer compute type.
    BF16,
    /// IEEE binary64 (rare; appears in reference paths).
    F64,
    /// Signed 32-bit integer (indices, device ids).
    S32,
    /// Unsigned 32-bit integer.
    U32,
    /// Signed 8-bit integer (quantized paths).
    S8,
    /// Boolean / predicate.
    Pred,
}

impl DType {
    /// HLO-text spelling (`f32`, `bf16`, ...).
    pub fn hlo_name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::F64 => "f64",
            DType::S32 => "s32",
            DType::U32 => "u32",
            DType::S8 => "s8",
            DType::Pred => "pred",
        }
    }

    /// Parse the HLO-text spelling.
    pub fn from_hlo_name(s: &str) -> Option<DType> {
        Some(match s {
            "f32" => DType::F32,
            "f16" => DType::F16,
            "bf16" => DType::BF16,
            "f64" => DType::F64,
            "s32" | "i32" => DType::S32,
            "u32" => DType::U32,
            "s8" | "i8" => DType::S8,
            "pred" | "i1" => DType::Pred,
            _ => return None,
        })
    }

    /// True for floating-point types.
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F16 | DType::BF16 | DType::F64)
    }

    /// Bytes per element.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F64 => 8,
            DType::F32 | DType::S32 | DType::U32 => 4,
            DType::F16 | DType::BF16 => 2,
            DType::S8 | DType::Pred => 1,
        }
    }

    /// Bit width of the significand, used by the precision-consistency
    /// analysis (paper bug category 3): a conversion that *loses* mantissa
    /// bits on only one side of the graph pair breaks equivalence.
    pub fn mantissa_bits(self) -> u32 {
        match self {
            DType::F64 => 52,
            DType::F32 => 23,
            DType::F16 => 10,
            DType::BF16 => 7,
            DType::S32 | DType::U32 => 31,
            DType::S8 => 7,
            DType::Pred => 1,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.hlo_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hlo_name_roundtrip() {
        for dt in [
            DType::F32,
            DType::F16,
            DType::BF16,
            DType::F64,
            DType::S32,
            DType::U32,
            DType::S8,
            DType::Pred,
        ] {
            assert_eq!(DType::from_hlo_name(dt.hlo_name()), Some(dt));
        }
        assert_eq!(DType::from_hlo_name("f8e4m3"), None);
    }

    #[test]
    fn precision_ordering_via_mantissa() {
        assert!(DType::F32.mantissa_bits() > DType::BF16.mantissa_bits());
        assert!(DType::F16.mantissa_bits() > DType::BF16.mantissa_bits());
    }

    #[test]
    fn sizes() {
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::F32.size_bytes(), 4);
    }
}
