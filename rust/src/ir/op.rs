//! Operators: the HLO subset emitted by production transformer pipelines.

use std::fmt;

/// Reduction combiner used by `reduce`, `all-reduce`, `reduce-scatter`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    /// Sum.
    Add,
    /// Maximum.
    Max,
    /// Minimum.
    Min,
    /// Product.
    Mul,
}

impl ReduceKind {
    /// HLO computation name (`add`, `maximum`, ...).
    pub fn hlo_name(self) -> &'static str {
        match self {
            ReduceKind::Add => "add",
            ReduceKind::Max => "maximum",
            ReduceKind::Min => "minimum",
            ReduceKind::Mul => "multiply",
        }
    }
}

/// Comparison direction for `compare`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CmpKind {
    /// Equal.
    Eq,
    /// Not equal.
    Ne,
    /// Less than.
    Lt,
    /// Less-or-equal.
    Le,
    /// Greater than.
    Gt,
    /// Greater-or-equal.
    Ge,
}

/// Replica groups of a collective: which cores participate together.
///
/// `groups[g]` lists the core ids of group `g`. A collective reduces /
/// gathers only *within* each group — wrong groups are the paper's bug
/// category 2 ("reducing on only part of the cores").
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ReplicaGroups(pub Vec<Vec<u32>>);

impl ReplicaGroups {
    /// All `n` cores in one group — the common full-mesh collective.
    pub fn full(n: u32) -> Self {
        ReplicaGroups(vec![(0..n).collect()])
    }

    /// `n` cores split into `k` contiguous groups.
    pub fn split(n: u32, k: u32) -> Self {
        assert!(k > 0 && n % k == 0);
        let per = n / k;
        ReplicaGroups(
            (0..k).map(|g| (g * per..(g + 1) * per).collect()).collect(),
        )
    }

    /// Total number of participating cores.
    pub fn core_count(&self) -> usize {
        self.0.iter().map(|g| g.len()).sum()
    }

    /// Group containing `core`, if any.
    pub fn group_of(&self, core: u32) -> Option<&[u32]> {
        self.0.iter().find(|g| g.contains(&core)).map(|g| g.as_slice())
    }

    /// True when every group has the same size.
    pub fn uniform(&self) -> bool {
        self.0.windows(2).all(|w| w[0].len() == w[1].len())
    }

    /// Order-insensitive canonical form: members ascending within each
    /// group, groups ordered by first member. Collective *reductions* are
    /// insensitive to listing order, so rules compare normalized forms;
    /// order-sensitive collectives (`all-gather` concat order) compare the
    /// raw listing.
    pub fn normalized(&self) -> ReplicaGroups {
        let mut groups: Vec<Vec<u32>> = self
            .0
            .iter()
            .map(|g| {
                let mut g = g.clone();
                g.sort_unstable();
                g
            })
            .collect();
        groups.sort_by_key(|g| g.first().copied().unwrap_or(u32::MAX));
        ReplicaGroups(groups)
    }

    /// Check that the groups form a partition of the `n`-core mesh:
    /// every group non-empty, every core id in `0..n`, no core in two
    /// groups (or twice in one), and every core covered. Returns a
    /// human-readable reason on the first violation — wrong-replica-group
    /// bugs that break these invariants would otherwise *silently*
    /// mis-evaluate (the interpreter treats an uncovered core as its own
    /// group, and an overlapping core reduces into several groups).
    pub fn check_partition(&self, n: u32) -> std::result::Result<(), String> {
        if self.0.is_empty() {
            return Err("collective has no replica groups".into());
        }
        let mut seen = vec![false; n as usize];
        for (gi, g) in self.0.iter().enumerate() {
            if g.is_empty() {
                return Err(format!("replica group {gi} is empty"));
            }
            for &core in g {
                if core >= n {
                    return Err(format!(
                        "replica group {gi} names core {core} but the mesh has {n} cores"
                    ));
                }
                if seen[core as usize] {
                    return Err(format!(
                        "core {core} appears in more than one replica group (groups must \
                         be disjoint)"
                    ));
                }
                seen[core as usize] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            return Err(format!(
                "core {missing} is not covered by any replica group (groups must \
                 partition the mesh)"
            ));
        }
        Ok(())
    }
}

/// Small constant payload. Large tensors never appear as literals in the
/// graphs we verify (weights are parameters), so an f64 vector suffices.
#[derive(Clone, Debug)]
pub enum ConstVal {
    /// Scalar constant.
    Scalar(f64),
    /// Dense little tensor (row-major, matches the node's shape).
    Dense(Vec<f64>),
}

impl ConstVal {
    /// All values in the payload.
    pub fn values(&self) -> &[f64] {
        match self {
            ConstVal::Scalar(v) => std::slice::from_ref(v),
            ConstVal::Dense(v) => v,
        }
    }
}

// Constants participate in hashing/equality for the e-graph's hash-consing;
// both equality and hashing use bit patterns, so -0.0 != 0.0 and
// NaN == NaN (by bits) — the right notion for structural equivalence of
// graphs, and the two MUST agree or hash-consing silently fails (a NaN
// constant that never dedups breaks cross-graph structural merging).
impl PartialEq for ConstVal {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (ConstVal::Scalar(a), ConstVal::Scalar(b)) => a.to_bits() == b.to_bits(),
            (ConstVal::Dense(a), ConstVal::Dense(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            _ => false,
        }
    }
}
impl Eq for ConstVal {}
impl std::hash::Hash for ConstVal {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        match self {
            ConstVal::Scalar(v) => {
                0u8.hash(state);
                v.to_bits().hash(state);
            }
            ConstVal::Dense(vs) => {
                1u8.hash(state);
                vs.len().hash(state);
                for v in vs {
                    v.to_bits().hash(state);
                }
            }
        }
    }
}

/// Operator kind of an IR node. Operand tensors are edges of the graph;
/// only non-tensor attributes live inside the enum.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Graph input (weights, activations, device-id tables).
    Parameter {
        /// Position in the entry computation's parameter list.
        index: usize,
        /// Human-readable name (e.g. `q_proj.weight`).
        name: String,
    },
    /// Compile-time constant.
    Constant(ConstVal),
    /// `iota` along `dim` (device-id/position tables).
    Iota {
        /// Dimension the counter runs along.
        dim: usize,
        /// Output dims (part of the op identity: the e-graph hash-conses
        /// by op + children, so shape-determining attributes must be here).
        dims: Vec<i64>,
    },

    // ---- elementwise binary ----
    /// Elementwise addition.
    Add,
    /// Elementwise subtraction.
    Sub,
    /// Elementwise multiplication.
    Mul,
    /// Elementwise division.
    Div,
    /// Elementwise maximum.
    Max,
    /// Elementwise minimum.
    Min,
    /// Elementwise power.
    Pow,

    // ---- elementwise unary ----
    /// Negation.
    Neg,
    /// Exponential.
    Exp,
    /// Natural log.
    Log,
    /// Hyperbolic tangent.
    Tanh,
    /// Reciprocal square root (RMSNorm).
    Rsqrt,
    /// Square root.
    Sqrt,
    /// Absolute value.
    Abs,
    /// Logistic sigmoid (SiLU = x * sigmoid(x)).
    Logistic,
    /// Sine (rotary embeddings).
    Sin,
    /// Cosine (rotary embeddings).
    Cos,
    /// dtype cast.
    Convert {
        /// Target element type.
        to: super::DType,
    },

    // ---- tensor algebra ----
    /// General dot: batch dims then contraction dims on each side.
    Dot {
        /// Contracted dimensions of the LHS.
        lhs_contract: Vec<usize>,
        /// Contracted dimensions of the RHS.
        rhs_contract: Vec<usize>,
        /// Batch dimensions of the LHS.
        lhs_batch: Vec<usize>,
        /// Batch dimensions of the RHS.
        rhs_batch: Vec<usize>,
    },

    // ---- data movement ----
    /// Reshape to `dims` (element order preserved). Target dims are part
    /// of the op identity — see `Iota` note.
    Reshape {
        /// Target dims.
        dims: Vec<i64>,
    },
    /// Dimension permutation: output dim `i` = input dim `perm[i]`.
    Transpose {
        /// Permutation, HLO convention.
        perm: Vec<usize>,
    },
    /// Static slice.
    Slice {
        /// Inclusive start per dimension.
        starts: Vec<i64>,
        /// Exclusive limit per dimension.
        limits: Vec<i64>,
        /// Stride per dimension (1 everywhere in our graphs).
        strides: Vec<i64>,
    },
    /// Concatenate along `dim`.
    Concat {
        /// Concatenation dimension.
        dim: usize,
    },
    /// `broadcast_in_dim`: `mapped[i]` is the output dim input dim `i` maps to.
    Broadcast {
        /// Output dimension for each input dimension.
        mapped: Vec<usize>,
        /// Output dims (part of the op identity — see `Iota` note).
        dims: Vec<i64>,
    },
    /// Reduce over `dims` with `kind`.
    Reduce {
        /// Combiner.
        kind: ReduceKind,
        /// Reduced (removed) dimensions.
        dims: Vec<usize>,
    },
    /// Elementwise select(pred, on_true, on_false).
    Select,
    /// Elementwise comparison producing `pred`.
    Compare(CmpKind),

    // ---- collectives (SPMD across the core mesh) ----
    /// Cross-core reduction; every core gets the reduced value.
    AllReduce {
        /// Combiner.
        kind: ReduceKind,
        /// Participating core groups.
        groups: ReplicaGroups,
    },
    /// Gather shards from cores along `dim`.
    AllGather {
        /// Concatenation dimension.
        dim: usize,
        /// Participating core groups.
        groups: ReplicaGroups,
    },
    /// Reduce across cores then scatter shards along `dim`.
    ReduceScatter {
        /// Combiner.
        kind: ReduceKind,
        /// Scatter dimension.
        dim: usize,
        /// Participating core groups.
        groups: ReplicaGroups,
    },
    /// Split along `split_dim`, exchange, concat along `concat_dim`.
    AllToAll {
        /// Dimension split across cores.
        split_dim: usize,
        /// Dimension the received chunks are concatenated along.
        concat_dim: usize,
        /// Participating core groups.
        groups: ReplicaGroups,
    },

    // ---- point-to-point (pipeline stage boundaries) ----
    /// Send the operand to the next pipeline stage over `channel`.
    ///
    /// Scalify's IR keeps the dataflow explicit: the matching [`Op::Recv`]
    /// consumes the send's value directly, so a send/recv pair has exact
    /// identity semantics (the tensor is relocated, not transformed). Real
    /// HLO threads tokens through send/recv; the simplified form is what
    /// the verifier's relation rules need — facts propagate through the
    /// boundary unchanged.
    Send {
        /// Channel id tying the send to its recv.
        channel: u32,
    },
    /// Receive the matching [`Op::Send`]'s value on the next stage.
    Recv {
        /// Channel id tying the recv to its send.
        channel: u32,
    },

    // ---- structure ----
    /// Tuple of operands (entry-computation outputs).
    Tuple,
    /// Project tuple element `index`.
    GetTupleElement {
        /// Element index.
        index: usize,
    },
    /// Opaque op the parser kept but analyses treat as uninterpreted.
    Custom {
        /// Op name as it appeared in HLO text.
        name: String,
    },
}

impl Op {
    /// Mnemonic used in HLO text and debug printing.
    pub fn name(&self) -> &str {
        match self {
            Op::Parameter { .. } => "parameter",
            Op::Constant(_) => "constant",
            Op::Iota { .. } => "iota",
            Op::Add => "add",
            Op::Sub => "subtract",
            Op::Mul => "multiply",
            Op::Div => "divide",
            Op::Max => "maximum",
            Op::Min => "minimum",
            Op::Pow => "power",
            Op::Neg => "negate",
            Op::Exp => "exponential",
            Op::Log => "log",
            Op::Tanh => "tanh",
            Op::Rsqrt => "rsqrt",
            Op::Sqrt => "sqrt",
            Op::Abs => "abs",
            Op::Logistic => "logistic",
            Op::Sin => "sine",
            Op::Cos => "cosine",
            Op::Convert { .. } => "convert",
            Op::Dot { .. } => "dot",
            Op::Reshape { .. } => "reshape",
            Op::Transpose { .. } => "transpose",
            Op::Slice { .. } => "slice",
            Op::Concat { .. } => "concatenate",
            Op::Broadcast { .. } => "broadcast",
            Op::Reduce { .. } => "reduce",
            Op::Select => "select",
            Op::Compare(_) => "compare",
            Op::AllReduce { .. } => "all-reduce",
            Op::AllGather { .. } => "all-gather",
            Op::ReduceScatter { .. } => "reduce-scatter",
            Op::AllToAll { .. } => "all-to-all",
            Op::Send { .. } => "send",
            Op::Recv { .. } => "recv",
            Op::Tuple => "tuple",
            Op::GetTupleElement { .. } => "get-tuple-element",
            Op::Custom { name } => name,
        }
    }

    /// True for elementwise ops (unary or binary or select/compare) — the
    /// class the relation analysis propagates shard/duplicate facts through
    /// unchanged.
    pub fn is_elementwise(&self) -> bool {
        matches!(
            self,
            Op::Add
                | Op::Sub
                | Op::Mul
                | Op::Div
                | Op::Max
                | Op::Min
                | Op::Pow
                | Op::Neg
                | Op::Exp
                | Op::Log
                | Op::Tanh
                | Op::Rsqrt
                | Op::Sqrt
                | Op::Abs
                | Op::Logistic
                | Op::Sin
                | Op::Cos
                | Op::Select
                | Op::Compare(_)
        )
    }

    /// True for the SPMD collectives.
    pub fn is_collective(&self) -> bool {
        matches!(
            self,
            Op::AllReduce { .. }
                | Op::AllGather { .. }
                | Op::ReduceScatter { .. }
                | Op::AllToAll { .. }
        )
    }

    /// True for pure data-movement (layout) ops.
    pub fn is_layout(&self) -> bool {
        matches!(self, Op::Reshape { .. } | Op::Transpose { .. })
    }

    /// True for the pipeline boundary ops (`send` / `recv`), which have
    /// identity value semantics.
    pub fn is_boundary(&self) -> bool {
        matches!(self, Op::Send { .. } | Op::Recv { .. })
    }

    /// Commutative binary elementwise ops (feeds e-graph rewrite rules).
    pub fn is_commutative(&self) -> bool {
        matches!(self, Op::Add | Op::Mul | Op::Max | Op::Min)
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_groups_full_and_split() {
        let g = ReplicaGroups::full(4);
        assert_eq!(g.0, vec![vec![0, 1, 2, 3]]);
        assert_eq!(g.core_count(), 4);
        let s = ReplicaGroups::split(8, 2);
        assert_eq!(s.0.len(), 2);
        assert_eq!(s.group_of(5), Some(&[4u32, 5, 6, 7][..]));
        assert!(s.uniform());
    }

    #[test]
    fn replica_groups_normalize_and_partition_check() {
        let g = ReplicaGroups(vec![vec![3, 1], vec![2, 0]]);
        assert_eq!(g.normalized().0, vec![vec![0, 2], vec![1, 3]]);
        assert!(g.check_partition(4).is_ok());
        // overlap
        let o = ReplicaGroups(vec![vec![0, 1], vec![1, 2, 3]]);
        assert!(o.check_partition(4).unwrap_err().contains("more than one"));
        // gap
        let gap = ReplicaGroups(vec![vec![0, 1], vec![2]]);
        assert!(gap.check_partition(4).unwrap_err().contains("not covered"));
        // out of bounds
        let oob = ReplicaGroups(vec![vec![0, 1, 2, 4]]);
        assert!(oob.check_partition(4).unwrap_err().contains("4 cores"));
        // empty group
        let empty = ReplicaGroups(vec![vec![0, 1, 2, 3], vec![]]);
        assert!(empty.check_partition(4).unwrap_err().contains("empty"));
    }

    #[test]
    fn op_classification() {
        assert!(Op::Add.is_elementwise());
        assert!(Op::Add.is_commutative());
        assert!(!Op::Sub.is_commutative());
        assert!(Op::Reshape { dims: vec![4] }.is_layout());
        assert!(Op::AllReduce { kind: ReduceKind::Add, groups: ReplicaGroups::full(2) }
            .is_collective());
        assert!(!Op::Dot {
            lhs_contract: vec![1],
            rhs_contract: vec![0],
            lhs_batch: vec![],
            rhs_batch: vec![]
        }
        .is_elementwise());
    }

    #[test]
    fn constval_hash_eq_by_bits() {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let h = |c: &ConstVal| {
            let mut s = DefaultHasher::new();
            c.hash(&mut s);
            s.finish()
        };
        assert_eq!(h(&ConstVal::Scalar(1.5)), h(&ConstVal::Scalar(1.5)));
        assert_ne!(h(&ConstVal::Scalar(0.0)), h(&ConstVal::Scalar(-0.0)));
    }
}
