//! Ergonomic graph construction with shape inference and source metadata.
//!
//! The model zoo ([`crate::modelgen`]) builds framework-style graphs
//! through this API; every helper infers the output shape the same way the
//! HLO verifier would, so structurally invalid graphs fail at construction
//! time, not at verification time.

use super::{CmpKind, ConstVal, DType, Graph, Meta, NodeId, Op, ReduceKind, ReplicaGroups, Shape};
use crate::util::Sym;

/// Shape inference for an op given operand shapes (per-core shapes for SPMD
/// graphs, hence `num_cores` for the collectives).
pub fn infer_shape(op: &Op, ins: &[&Shape], num_cores: u32) -> Shape {
    match op {
        Op::Parameter { .. } | Op::Constant(_) => {
            unreachable!("leaf shapes are given, not inferred")
        }
        Op::Iota { dims, .. } => Shape::new(super::DType::S32, dims.clone()),
        Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Max | Op::Min | Op::Pow => {
            broadcast_binary(ins[0], ins[1])
        }
        Op::Neg
        | Op::Exp
        | Op::Log
        | Op::Tanh
        | Op::Rsqrt
        | Op::Sqrt
        | Op::Abs
        | Op::Logistic
        | Op::Sin
        | Op::Cos => ins[0].clone(),
        Op::Convert { to } => ins[0].with_dtype(*to),
        Op::Compare(_) => broadcast_binary(ins[0], ins[1]).with_dtype(DType::Pred),
        Op::Select => ins[1].clone(),
        Op::Dot { lhs_contract, rhs_contract, lhs_batch, rhs_batch } => {
            let lhs = ins[0];
            let rhs = ins[1];
            let mut dims: Vec<i64> = lhs_batch.iter().map(|&d| lhs.dims[d]).collect();
            for (i, &d) in lhs.dims.iter().enumerate() {
                if !lhs_contract.contains(&i) && !lhs_batch.contains(&i) {
                    dims.push(d);
                }
            }
            for (i, &d) in rhs.dims.iter().enumerate() {
                if !rhs_contract.contains(&i) && !rhs_batch.contains(&i) {
                    dims.push(d);
                }
            }
            Shape::new(lhs.dtype, dims)
        }
        Op::Reshape { dims } => ins[0].with_dims(dims.clone()),
        Op::Transpose { perm } => {
            let dims = perm.iter().map(|&p| ins[0].dims[p]).collect();
            ins[0].with_dims(dims)
        }
        Op::Slice { starts, limits, strides } => {
            let dims = starts
                .iter()
                .zip(limits)
                .zip(strides)
                .map(|((&s, &l), &st)| (l - s + st - 1) / st)
                .collect();
            ins[0].with_dims(dims)
        }
        Op::Concat { dim } => {
            let mut dims = ins[0].dims.clone();
            dims[*dim] = ins.iter().map(|s| s.dims[*dim]).sum();
            ins[0].with_dims(dims)
        }
        Op::Broadcast { dims, .. } => ins[0].with_dims(dims.clone()),
        Op::Reduce { dims, .. } => {
            let out = ins[0]
                .dims
                .iter()
                .enumerate()
                .filter(|(i, _)| !dims.contains(i))
                .map(|(_, &d)| d)
                .collect();
            ins[0].with_dims(out)
        }
        Op::AllReduce { .. } | Op::Send { .. } | Op::Recv { .. } => ins[0].clone(),
        Op::AllGather { dim, groups } => {
            let g = groups.0[0].len() as i64;
            let mut dims = ins[0].dims.clone();
            dims[*dim] *= g;
            ins[0].with_dims(dims)
        }
        Op::ReduceScatter { dim, groups, .. } => {
            let g = groups.0[0].len() as i64;
            let mut dims = ins[0].dims.clone();
            assert_eq!(dims[*dim] % g, 0, "reduce-scatter dim not divisible");
            dims[*dim] /= g;
            ins[0].with_dims(dims)
        }
        Op::AllToAll { split_dim, concat_dim, groups } => {
            let g = groups.0[0].len() as i64;
            let mut dims = ins[0].dims.clone();
            assert_eq!(dims[*split_dim] % g, 0, "all-to-all split dim not divisible");
            dims[*split_dim] /= g;
            dims[*concat_dim] *= g;
            let _ = num_cores;
            ins[0].with_dims(dims)
        }
        Op::Tuple => Shape::scalar(ins.first().map(|s| s.dtype).unwrap_or(DType::F32)),
        Op::GetTupleElement { .. } => unreachable!("tuple element shapes tracked by caller"),
        Op::Custom { .. } => ins[0].clone(),
    }
}

fn broadcast_binary(a: &Shape, b: &Shape) -> Shape {
    // Scalars broadcast against anything; otherwise shapes must match
    // (HLO requires explicit broadcasts, which our builders insert).
    if a.rank() == 0 {
        return b.clone();
    }
    if b.rank() == 0 {
        return a.clone();
    }
    assert_eq!(a.dims, b.dims, "binary op on mismatched shapes {} vs {}", a, b);
    a.clone()
}

/// Source-context state carried onto every node the builder creates.
#[derive(Clone, Copy, Debug)]
struct SourceCtx {
    file: Sym,
    line: u32,
    func: Sym,
    layer: Option<u32>,
    stage: Option<u32>,
}

/// Builder over a [`Graph`] with shape inference and source tracking.
pub struct GraphBuilder {
    g: Graph,
    ctx: SourceCtx,
    next_param: usize,
}

impl GraphBuilder {
    /// Start building a graph named `name` over `num_cores` cores.
    pub fn new(name: impl Into<String>, num_cores: u32) -> GraphBuilder {
        GraphBuilder {
            g: Graph::new(name, num_cores),
            ctx: SourceCtx {
                file: Sym::EMPTY,
                line: 0,
                func: Sym::EMPTY,
                layer: None,
                stage: None,
            },
            next_param: 0,
        }
    }

    /// Set the source file/line attached to subsequently built nodes.
    pub fn at(&mut self, file: &str, line: u32) -> &mut Self {
        self.ctx.file = self.g.interner.intern(file);
        self.ctx.line = line;
        self
    }

    /// Set the enclosing framework function name.
    pub fn in_func(&mut self, func: &str) -> &mut Self {
        self.ctx.func = self.g.interner.intern(func);
        self
    }

    /// Set the current layer index (None = outside any layer).
    pub fn layer(&mut self, layer: Option<u32>) -> &mut Self {
        self.ctx.layer = layer;
        self
    }

    /// Set the current pipeline stage (None = not pipeline-owned).
    pub fn stage(&mut self, stage: Option<u32>) -> &mut Self {
        self.ctx.stage = stage;
        self
    }

    fn meta(&mut self, expr: &str) -> Meta {
        Meta {
            file: self.ctx.file,
            line: self.ctx.line,
            expr: self.g.interner.intern(expr),
            func: self.ctx.func,
            layer: self.ctx.layer,
            stage: self.ctx.stage,
        }
    }

    fn push_infer(&mut self, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let shapes: Vec<&Shape> = inputs.iter().map(|&i| &self.g.node(i).shape).collect();
        let shape = infer_shape(&op, &shapes, self.g.num_cores);
        let meta = self.meta(op.name());
        self.g.push(op, inputs, shape, meta)
    }

    // ---- leaves ----

    /// New parameter with the next parameter index.
    pub fn parameter(&mut self, name: &str, shape: Shape) -> NodeId {
        let index = self.next_param;
        self.next_param += 1;
        let meta = self.meta(&format!("param {name}"));
        self.g.push(Op::Parameter { index, name: name.to_owned() }, vec![], shape, meta)
    }

    /// Scalar constant.
    pub fn constant(&mut self, v: f64, dtype: DType) -> NodeId {
        let meta = self.meta(&format!("const {v}"));
        self.g.push(Op::Constant(ConstVal::Scalar(v)), vec![], Shape::scalar(dtype), meta)
    }

    /// Dense constant (row-major values matching `shape`).
    pub fn dense_constant(&mut self, values: Vec<f64>, shape: Shape) -> NodeId {
        assert_eq!(values.len() as i64, shape.elements());
        let meta = self.meta("const dense");
        self.g.push(Op::Constant(ConstVal::Dense(values)), vec![], shape, meta)
    }

    /// `iota` along `dim` of the given shape (device/position ids).
    pub fn iota(&mut self, shape: Shape, dim: usize) -> NodeId {
        let meta = self.meta("iota");
        let dims = shape.dims.clone();
        self.g.push(Op::Iota { dim, dims }, vec![], shape, meta)
    }

    // ---- elementwise ----

    /// x + y
    pub fn add(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Add, vec![x, y])
    }
    /// x - y
    pub fn sub(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Sub, vec![x, y])
    }
    /// x * y
    pub fn mul(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Mul, vec![x, y])
    }
    /// x / y
    pub fn div(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Div, vec![x, y])
    }
    /// max(x, y)
    pub fn max(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Max, vec![x, y])
    }
    /// min(x, y)
    pub fn min(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Min, vec![x, y])
    }
    /// x ** y
    pub fn pow(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Pow, vec![x, y])
    }
    /// -x
    pub fn neg(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Neg, vec![x])
    }
    /// e^x
    pub fn exp(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Exp, vec![x])
    }
    /// ln x
    pub fn log(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Log, vec![x])
    }
    /// tanh x
    pub fn tanh(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Tanh, vec![x])
    }
    /// 1/sqrt(x)
    pub fn rsqrt(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Rsqrt, vec![x])
    }
    /// sqrt x
    pub fn sqrt(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Sqrt, vec![x])
    }
    /// |x|
    pub fn abs(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Abs, vec![x])
    }
    /// sigmoid(x)
    pub fn logistic(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Logistic, vec![x])
    }
    /// sin x
    pub fn sin(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Sin, vec![x])
    }
    /// cos x
    pub fn cos(&mut self, x: NodeId) -> NodeId {
        self.push_infer(Op::Cos, vec![x])
    }
    /// cast to `to`
    pub fn convert(&mut self, x: NodeId, to: DType) -> NodeId {
        self.push_infer(Op::Convert { to }, vec![x])
    }
    /// select(pred, t, f)
    pub fn select(&mut self, pred: NodeId, t: NodeId, f: NodeId) -> NodeId {
        self.push_infer(Op::Select, vec![pred, t, f])
    }
    /// compare(x, y)
    pub fn compare(&mut self, kind: CmpKind, x: NodeId, y: NodeId) -> NodeId {
        self.push_infer(Op::Compare(kind), vec![x, y])
    }

    // ---- algebra ----

    /// Plain 2-D (or batched last-two-dims) matmul: contracts the last dim
    /// of `x` with the second-to-last of `y`, batching leading dims of both.
    pub fn matmul(&mut self, x: NodeId, y: NodeId) -> NodeId {
        let xr = self.g.node(x).shape.rank();
        let yr = self.g.node(y).shape.rank();
        assert!(xr >= 2 && yr >= 2, "matmul needs rank >= 2");
        let batch = xr.min(yr) - 2;
        let op = Op::Dot {
            lhs_contract: vec![xr - 1],
            rhs_contract: vec![yr - 2],
            lhs_batch: (0..batch).collect(),
            rhs_batch: (0..batch).collect(),
        };
        self.push_infer(op, vec![x, y])
    }

    /// Fully general dot.
    pub fn dot_general(
        &mut self,
        x: NodeId,
        y: NodeId,
        lhs_contract: Vec<usize>,
        rhs_contract: Vec<usize>,
        lhs_batch: Vec<usize>,
        rhs_batch: Vec<usize>,
    ) -> NodeId {
        self.push_infer(Op::Dot { lhs_contract, rhs_contract, lhs_batch, rhs_batch }, vec![x, y])
    }

    // ---- data movement ----

    /// reshape to `dims`
    pub fn reshape(&mut self, x: NodeId, dims: Vec<i64>) -> NodeId {
        let in_shape = self.g.node(x).shape.clone();
        assert_eq!(
            in_shape.elements(),
            dims.iter().product::<i64>(),
            "reshape {} -> {:?} changes element count",
            in_shape,
            dims
        );
        let meta = self.meta("reshape");
        self.g
            .push(Op::Reshape { dims: dims.clone() }, vec![x], in_shape.with_dims(dims), meta)
    }

    /// transpose by `perm`
    pub fn transpose(&mut self, x: NodeId, perm: Vec<usize>) -> NodeId {
        self.push_infer(Op::Transpose { perm }, vec![x])
    }

    /// slice `[starts, limits)` with stride 1
    pub fn slice(&mut self, x: NodeId, starts: Vec<i64>, limits: Vec<i64>) -> NodeId {
        let strides = vec![1i64; starts.len()];
        self.push_infer(Op::Slice { starts, limits, strides }, vec![x])
    }

    /// Slice only `dim` to `[start, limit)`, other dims kept whole.
    pub fn slice_dim(&mut self, x: NodeId, dim: usize, start: i64, limit: i64) -> NodeId {
        let shape = self.g.node(x).shape.clone();
        let mut starts = vec![0i64; shape.rank()];
        let mut limits = shape.dims.clone();
        starts[dim] = start;
        limits[dim] = limit;
        self.slice(x, starts, limits)
    }

    /// concat along `dim`
    pub fn concat(&mut self, xs: Vec<NodeId>, dim: usize) -> NodeId {
        self.push_infer(Op::Concat { dim }, xs)
    }

    /// broadcast_in_dim to `out_dims`, mapping input dim i to `mapped[i]`
    pub fn broadcast(&mut self, x: NodeId, out_dims: Vec<i64>, mapped: Vec<usize>) -> NodeId {
        let in_shape = self.g.node(x).shape.clone();
        assert_eq!(mapped.len(), in_shape.rank());
        let meta = self.meta("broadcast");
        self.g.push(
            Op::Broadcast { mapped, dims: out_dims.clone() },
            vec![x],
            in_shape.with_dims(out_dims),
            meta,
        )
    }

    /// Broadcast a scalar to `dims`.
    pub fn broadcast_scalar(&mut self, x: NodeId, dims: Vec<i64>) -> NodeId {
        self.broadcast(x, dims, vec![])
    }

    /// reduce over `dims`
    pub fn reduce(&mut self, x: NodeId, kind: ReduceKind, dims: Vec<usize>) -> NodeId {
        self.push_infer(Op::Reduce { kind, dims }, vec![x])
    }

    // ---- collectives ----

    /// all-reduce across `groups`
    pub fn all_reduce(&mut self, x: NodeId, kind: ReduceKind, groups: ReplicaGroups) -> NodeId {
        self.push_infer(Op::AllReduce { kind, groups }, vec![x])
    }

    /// all-gather along `dim`
    pub fn all_gather(&mut self, x: NodeId, dim: usize, groups: ReplicaGroups) -> NodeId {
        self.push_infer(Op::AllGather { dim, groups }, vec![x])
    }

    /// reduce-scatter along `dim`
    pub fn reduce_scatter(
        &mut self,
        x: NodeId,
        kind: ReduceKind,
        dim: usize,
        groups: ReplicaGroups,
    ) -> NodeId {
        self.push_infer(Op::ReduceScatter { kind, dim, groups }, vec![x])
    }

    /// all-to-all
    pub fn all_to_all(
        &mut self,
        x: NodeId,
        split_dim: usize,
        concat_dim: usize,
        groups: ReplicaGroups,
    ) -> NodeId {
        self.push_infer(Op::AllToAll { split_dim, concat_dim, groups }, vec![x])
    }

    // ---- point-to-point ----

    /// send to the next pipeline stage over `channel`
    pub fn send(&mut self, x: NodeId, channel: u32) -> NodeId {
        self.push_infer(Op::Send { channel }, vec![x])
    }

    /// recv the matching send's value
    pub fn recv(&mut self, x: NodeId, channel: u32) -> NodeId {
        self.push_infer(Op::Recv { channel }, vec![x])
    }

    // ---- structure ----

    /// Mark `x` as a graph output.
    pub fn output(&mut self, x: NodeId) {
        self.g.outputs.push(x);
    }

    /// Shape of an already-built node.
    pub fn shape_of(&self, x: NodeId) -> &Shape {
        &self.g.node(x).shape
    }

    /// Finish and return the graph.
    pub fn finish(self) -> Graph {
        self.g
    }

    /// Peek at the graph under construction.
    pub fn graph(&self) -> &Graph {
        &self.g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32s(dims: &[i64]) -> Shape {
        Shape::new(DType::F32, dims.to_vec())
    }

    #[test]
    fn matmul_shapes() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", f32s(&[4, 8]));
        let w = b.parameter("w", f32s(&[8, 16]));
        let y = b.matmul(x, w);
        assert_eq!(b.shape_of(y).dims, vec![4, 16]);
    }

    #[test]
    fn batched_matmul_shapes() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", f32s(&[2, 4, 8]));
        let w = b.parameter("w", f32s(&[2, 8, 16]));
        let y = b.matmul(x, w);
        assert_eq!(b.shape_of(y).dims, vec![2, 4, 16]);
    }

    #[test]
    fn transpose_reshape_slice_shapes() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", f32s(&[2, 3, 4]));
        let t = b.transpose(x, vec![2, 0, 1]);
        assert_eq!(b.shape_of(t).dims, vec![4, 2, 3]);
        let r = b.reshape(t, vec![8, 3]);
        assert_eq!(b.shape_of(r).dims, vec![8, 3]);
        let s = b.slice_dim(r, 0, 2, 6);
        assert_eq!(b.shape_of(s).dims, vec![4, 3]);
    }

    #[test]
    fn collective_shapes() {
        let mut b = GraphBuilder::new("t", 4);
        let x = b.parameter("x", f32s(&[8, 16]));
        let ar = b.all_reduce(x, ReduceKind::Add, ReplicaGroups::full(4));
        assert_eq!(b.shape_of(ar).dims, vec![8, 16]);
        let ag = b.all_gather(x, 0, ReplicaGroups::full(4));
        assert_eq!(b.shape_of(ag).dims, vec![32, 16]);
        let rs = b.reduce_scatter(x, ReduceKind::Add, 1, ReplicaGroups::full(4));
        assert_eq!(b.shape_of(rs).dims, vec![8, 4]);
        let a2a = b.all_to_all(x, 0, 1, ReplicaGroups::full(4));
        assert_eq!(b.shape_of(a2a).dims, vec![2, 64]);
    }

    #[test]
    fn reduce_and_broadcast_shapes() {
        let mut b = GraphBuilder::new("t", 1);
        let x = b.parameter("x", f32s(&[4, 8, 16]));
        let r = b.reduce(x, ReduceKind::Max, vec![2]);
        assert_eq!(b.shape_of(r).dims, vec![4, 8]);
        let bc = b.broadcast(r, vec![4, 8, 16], vec![0, 1]);
        assert_eq!(b.shape_of(bc).dims, vec![4, 8, 16]);
        let s = b.constant(2.0, DType::F32);
        let bs = b.broadcast_scalar(s, vec![4, 4]);
        assert_eq!(b.shape_of(bs).dims, vec![4, 4]);
    }

    #[test]
    fn source_context_recorded() {
        let mut b = GraphBuilder::new("t", 1);
        b.at("attention.py", 42).in_func("attn_fwd").layer(Some(3));
        let x = b.parameter("x", f32s(&[2]));
        let e = b.exp(x);
        let g = b.finish();
        assert_eq!(g.source_site(e), "attention.py:42");
        assert_eq!(g.node(e).meta.layer, Some(3));
        assert_eq!(g.interner.resolve(g.node(e).meta.func), "attn_fwd");
        assert_eq!(g.source_site(x), "attention.py:42");
    }
}
