//! Tensor IR: the computational-graph representation Scalify verifies.
//!
//! The IR mirrors the HLO subset that production frameworks (XLA backends,
//! Transformers-NeuronX-style compilers, JAX lowering) emit for transformer
//! inference graphs: dense algebra (`dot`, elementwise), data movement
//! (`reshape`, `transpose`, `slice`, `concatenate`, `broadcast`),
//! reductions, and the SPMD collectives (`all-reduce`, `all-gather`,
//! `reduce-scatter`, `all-to-all`).
//!
//! A [`Graph`] is an arena of [`Node`]s in def-before-use order. Distributed
//! graphs are SPMD: one graph executed on `c` cores, with collectives
//! operating across a replica mesh. Cross-graph facts (which parameter of
//! the distributed graph is a shard of which baseline tensor) live in
//! [`Annotation`]s, mirroring the sharding annotations Scalify's compiler
//! instrumentation records during IR generation (§5.2.1).

mod dtype;
mod shape;
mod op;
mod graph;
mod builder;
mod annotate;
mod mesh;

pub use annotate::{Annotation, InputRelation};
pub use builder::{infer_shape, GraphBuilder};
pub use dtype::DType;
pub use graph::{Graph, Meta, Node, NodeId};
pub use mesh::{AxesMask, Mesh};
pub use op::{CmpKind, ConstVal, Op, ReduceKind, ReplicaGroups};
pub use shape::Shape;
