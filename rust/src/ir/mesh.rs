//! Device-mesh geometry: named axes over the SPMD core space.
//!
//! A distributed graph's cores form a logical mesh `[a0, a1, …]` (row
//! major: the **last** axis varies fastest). Core `r`'s coordinate along
//! axis `k` is the mixed-radix digit `(r / stride_k) % size_k`. Subgroup
//! collectives operate over the groups of cores that differ *only* in a
//! chosen subset of axes — [`Mesh::groups_for`] materializes those groups
//! as concrete [`ReplicaGroups`], which is how an "all-reduce over the tp
//! axis" of a `dp×tp` mesh becomes `replica_groups={{0,1},{2,3}}`.
//!
//! Axis subsets are passed as bitmasks (`1 << axis`), small enough for
//! any realistic mesh and cheap to store inside relation facts.

use super::ReplicaGroups;

/// Bitmask over mesh axes (`1 << axis`).
pub type AxesMask = u8;

/// Logical device mesh: ordered axis sizes, last axis fastest.
///
/// A 1-axis mesh `[n]` is the classic flat SPMD view every pre-mesh
/// scenario uses; `[dp, tp]` is the SPMD slice of a `pp×dp×tp` plan (the
/// pipeline axis stays metadata — stages, not SPMD width).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Mesh {
    /// Axis sizes, slowest first.
    pub axes: Vec<u32>,
}

impl Mesh {
    /// Flat 1-axis mesh over `n` cores.
    pub fn flat(n: u32) -> Mesh {
        Mesh { axes: vec![n.max(1)] }
    }

    /// Mesh from explicit axis sizes (empty ⇒ flat over 1 core).
    pub fn new(axes: Vec<u32>) -> Mesh {
        if axes.is_empty() {
            Mesh::flat(1)
        } else {
            Mesh { axes }
        }
    }

    /// Number of axes.
    pub fn rank(&self) -> usize {
        self.axes.len()
    }

    /// Total core count (product of axis sizes).
    pub fn total(&self) -> u32 {
        self.axes.iter().product()
    }

    /// Size of axis `k`.
    pub fn size(&self, k: usize) -> u32 {
        self.axes[k]
    }

    /// Stride of axis `k` in the flat core index (product of faster axes).
    pub fn stride(&self, k: usize) -> u32 {
        self.axes[k + 1..].iter().product()
    }

    /// Core `r`'s digit along axis `k`.
    pub fn digit(&self, r: u32, k: usize) -> u32 {
        (r / self.stride(k)) % self.axes[k]
    }

    /// Mask covering every axis.
    pub fn full_mask(&self) -> AxesMask {
        ((1u16 << self.rank()) - 1) as AxesMask
    }

    /// Drop degenerate (size-1) axes from a mask: reducing over a size-1
    /// axis is a no-op, so masks differing only there are equivalent.
    pub fn normalize_mask(&self, mask: AxesMask) -> AxesMask {
        let mut out = 0;
        for k in 0..self.rank() {
            if mask & (1 << k) != 0 && self.axes[k] > 1 {
                out |= 1 << k;
            }
        }
        out
    }

    /// Cores per group for an axis subset (product of the masked sizes).
    pub fn group_size(&self, mask: AxesMask) -> u32 {
        (0..self.rank())
            .filter(|&k| mask & (1 << k) != 0)
            .map(|k| self.axes[k])
            .product()
    }

    /// The replica groups of a collective over the masked axes: cores that
    /// agree on every *unmasked* digit form one group. Members are listed
    /// in ascending core id (= row-major order of the masked digits), and
    /// groups in ascending order of their first member — the canonical
    /// form every engine-emitted collective uses.
    pub fn groups_for(&self, mask: AxesMask) -> ReplicaGroups {
        let total = self.total();
        let mut rep: Vec<Option<usize>> = vec![None; total as usize];
        let mut groups: Vec<Vec<u32>> = Vec::new();
        for r in 0..total {
            // key = core with masked digits zeroed
            let mut key = r;
            for k in 0..self.rank() {
                if mask & (1 << k) != 0 {
                    key -= self.digit(r, k) * self.stride(k);
                }
            }
            match rep[key as usize] {
                Some(g) => groups[g].push(r),
                None => {
                    rep[key as usize] = Some(groups.len());
                    groups.push(vec![r]);
                }
            }
        }
        ReplicaGroups(groups)
    }

    /// The axis subset whose [`Mesh::groups_for`] equals `groups`
    /// (order-insensitively), if any. This is how group-aware relation
    /// rules map a concrete collective back onto mesh axes; a collective
    /// whose groups match no axis subset gets no rule — the wrong-group
    /// bug family surfaces as an unverified frontier there.
    pub fn axes_of_groups(&self, groups: &ReplicaGroups) -> Option<AxesMask> {
        let want = groups.normalized();
        for mask in 0..=self.full_mask() {
            if self.groups_for(mask).normalized() == want {
                return Some(mask);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_mesh_is_one_full_group() {
        let m = Mesh::flat(4);
        assert_eq!(m.total(), 4);
        assert_eq!(m.groups_for(1).0, vec![vec![0, 1, 2, 3]]);
        assert_eq!(m.groups_for(0).0.len(), 4); // empty mask = singletons
    }

    #[test]
    fn dp_tp_mesh_groups() {
        // mesh [dp=2, tp=2]: core = d*2 + t
        let m = Mesh::new(vec![2, 2]);
        assert_eq!(m.stride(0), 2);
        assert_eq!(m.stride(1), 1);
        assert_eq!(m.digit(3, 0), 1);
        assert_eq!(m.digit(3, 1), 1);
        // tp axis (bit 1): contiguous pairs
        assert_eq!(m.groups_for(1 << 1).0, vec![vec![0, 1], vec![2, 3]]);
        // dp axis (bit 0): strided pairs
        assert_eq!(m.groups_for(1 << 0).0, vec![vec![0, 2], vec![1, 3]]);
        // both axes: the full mesh
        assert_eq!(m.groups_for(m.full_mask()).0, vec![vec![0, 1, 2, 3]]);
    }

    #[test]
    fn axes_of_groups_inverts_groups_for() {
        let m = Mesh::new(vec![2, 4]);
        for mask in 0..=m.full_mask() {
            assert_eq!(m.axes_of_groups(&m.groups_for(mask)), Some(mask));
        }
        // a permuted listing still maps back (normalized comparison)
        let mut g = m.groups_for(1 << 1);
        g.0.reverse();
        assert_eq!(m.axes_of_groups(&g), Some(1 << 1));
        // groups that are no axis subset map to nothing
        let bogus = ReplicaGroups(vec![vec![0, 3], vec![1, 2], vec![4, 7], vec![5, 6]]);
        assert_eq!(m.axes_of_groups(&bogus), None);
    }

    #[test]
    fn three_axis_strides() {
        let m = Mesh::new(vec![2, 3, 4]);
        assert_eq!(m.total(), 24);
        assert_eq!(m.stride(0), 12);
        assert_eq!(m.stride(1), 4);
        assert_eq!(m.stride(2), 1);
        assert_eq!(m.group_size(0b101), 8);
        let g = m.groups_for(1 << 2);
        assert_eq!(g.0.len(), 6);
        assert_eq!(g.0[0], vec![0, 1, 2, 3]);
        assert_eq!(g.0[1], vec![4, 5, 6, 7]);
    }
}
