//! Engine unit tests on hand-rolled micro baselines. The model-zoo-scale
//! differential tests live in `modelgen::tests`, `proptest` and
//! `tests/transform_engine.rs`.

use super::shard::shard_transform;
use super::*;
use crate::baseline::numerical_verify;
use crate::ir::{DType, Graph, GraphBuilder, Shape};
use crate::modelgen::Parallelism;
use crate::verifier::{Session, VerifyConfig};

fn f32s(dims: &[i64]) -> Shape {
    Shape::new(DType::F32, dims.to_vec())
}

fn session() -> Session {
    Session::new(VerifyConfig { parallel: false, ..VerifyConfig::default() })
}

/// Y = X·W baseline for the matmul micro-tests.
fn matmul_base() -> Graph {
    let mut b = GraphBuilder::new("mm_base", 1);
    b.at("mlp.py", 10).in_func("mlp_fwd").layer(Some(0));
    let x = b.parameter("x", f32s(&[4, 16]));
    let w = b.parameter("w", f32s(&[16, 8]));
    let y = b.matmul(x, w);
    b.output(y);
    b.finish()
}

#[test]
fn contracted_shard_discharges_with_allreduce() {
    // Figure 3: X sharded dim1, W sharded dim0 → local dot is a partial,
    // the engine discharges it at the graph output
    let base = matmul_base();
    let plan = ParallelPlan::new(Parallelism::Tensor { tp: 4 })
        .shard("x", 1)
        .shard("w", 0);
    let pair = apply(&base, &plan).unwrap();
    assert_eq!(pair.dist.num_cores, 4);
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "all-reduce"));
    let report = session().verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
    assert!(numerical_verify(&pair, 2, 1e-4, 7).equivalent);
}

#[test]
fn column_shard_gathers_at_output() {
    let base = matmul_base();
    let plan = ParallelPlan::new(Parallelism::Tensor { tp: 2 }).shard("w", 1);
    let pair = apply(&base, &plan).unwrap();
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "all-gather"));
    let report = session().verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
    assert!(numerical_verify(&pair, 2, 1e-4, 11).equivalent);
}

#[test]
fn degree_one_is_identity() {
    let base = matmul_base();
    let plan = ParallelPlan::new(Parallelism::Tensor { tp: 1 }).shard("w", 1);
    let (dist, ann) = shard_transform(&base, &plan, &[1]).unwrap();
    assert_eq!(dist.len(), base.len());
    assert_eq!(ann.len(), 2);
}

/// One tanh-MLP training-ish micro baseline for the mesh tests: X·W then
/// a second contraction back to the hidden size.
fn two_matmul_base() -> Graph {
    let mut b = GraphBuilder::new("mm2_base", 1);
    b.at("mlp.py", 10).in_func("mlp_fwd").layer(Some(0));
    let x = b.parameter("x", f32s(&[4, 8]));
    let w0 = b.parameter("w0", f32s(&[8, 8]));
    let h = b.matmul(x, w0);
    let a = b.tanh(h);
    b.layer(Some(1)).at("mlp.py", 14);
    let w1 = b.parameter("w1", f32s(&[8, 8]));
    let y = b.matmul(a, w1);
    b.output(y);
    b.finish()
}

#[test]
fn mesh_plan_emits_subgroup_collectives() {
    use crate::ir::Mesh;
    // dp batch-shard on axis 0, tp column/row weight shard on axis 1:
    // the row-contraction partial discharges with a tp-subgroup
    // all-reduce ({{0,1},{2,3}}), not the full mesh
    let base = two_matmul_base();
    let plan = ParallelPlan::new(Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 })
        .shard_on("x", 0, 0)
        .shard_on("w0", 1, 1)
        .shard_on("w1", 0, 1);
    let pair = apply(&base, &plan).unwrap();
    assert_eq!(pair.dist.num_cores, 4);
    assert_eq!(pair.dist.mesh, vec![2, 2]);
    let mesh = Mesh::new(vec![2, 2]);
    let tp_groups = mesh.groups_for(1 << 1);
    let found = pair.dist.nodes.iter().any(|n| match &n.op {
        crate::ir::Op::AllReduce { groups, .. } => *groups == tp_groups,
        _ => false,
    });
    assert!(found, "expected a tp-subgroup all-reduce over {{0,1}},{{2,3}}");
    let report = session().verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
    assert!(numerical_verify(&pair, 2, 1e-4, 17).equivalent);
}

#[test]
fn mesh_gradient_style_contraction_uses_dp_groups() {
    use crate::ir::Mesh;
    // gW = Xᵀ·T with both operands batch-sharded over dp: the contraction
    // leaves a dp partial, discharged (at the replicated output) by an
    // all-reduce over the STRIDED dp groups {{0,2},{1,3}}
    let mut b = GraphBuilder::new("grad_base", 1);
    b.at("backward.py", 16).in_func("backward").layer(Some(0));
    let x = b.parameter("x", f32s(&[8, 4]));
    let t = b.parameter("t", f32s(&[8, 4]));
    let g = b.dot_general(x, t, vec![0], vec![0], vec![], vec![]);
    b.output(g);
    let base = b.finish();
    let plan = ParallelPlan::new(Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 })
        .shard_on("x", 0, 0)
        .shard_on("t", 0, 0);
    let pair = apply(&base, &plan).unwrap();
    let mesh = Mesh::new(vec![2, 2]);
    let dp_groups = mesh.groups_for(1 << 0);
    assert_eq!(dp_groups.0, vec![vec![0, 2], vec![1, 3]]);
    let found = pair.dist.nodes.iter().any(|n| match &n.op {
        crate::ir::Op::AllReduce { groups, .. } => *groups == dp_groups,
        _ => false,
    });
    assert!(found, "expected a dp-subgroup all-reduce over {{0,2}},{{1,3}}");
    let report = session().verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
    assert!(numerical_verify(&pair, 2, 1e-4, 19).equivalent);
}

#[test]
fn mesh_with_pipeline_keeps_width_and_mesh() {
    let base = layered_base();
    let plan = ParallelPlan::new(Parallelism::Mesh3D { pp: 2, dp: 2, tp: 2 })
        .shard_on("w0", 1, 1)
        .shard_on("w1", 0, 1);
    let pair = apply(&base, &plan).unwrap();
    pair.dist.validate().unwrap();
    assert_eq!(pair.dist.num_cores, 4);
    assert_eq!(pair.dist.mesh, vec![2, 2]);
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "send"));
    let report = session().verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
    assert!(numerical_verify(&pair, 2, 1e-4, 23).equivalent);
}

#[test]
fn wrong_subgroup_allreduce_fails_to_verify() {
    use crate::ir::{Mesh, Op};
    // mutate the tp-subgroup all-reduce to dp groups: numerics break and
    // the verifier localizes the collective
    let base = two_matmul_base();
    let plan = ParallelPlan::new(Parallelism::Mesh3D { pp: 1, dp: 2, tp: 2 })
        .shard_on("x", 0, 0)
        .shard_on("w0", 1, 1)
        .shard_on("w1", 0, 1);
    let mut pair = apply(&base, &plan).unwrap();
    let mesh = Mesh::new(vec![2, 2]);
    let dp_groups = mesh.groups_for(1 << 0);
    let tp_groups = mesh.groups_for(1 << 1);
    let mut mutated = false;
    for n in pair.dist.nodes.iter_mut() {
        if let Op::AllReduce { groups, .. } = &mut n.op {
            if *groups == tp_groups {
                *groups = dp_groups.clone();
                mutated = true;
                break;
            }
        }
    }
    assert!(mutated, "no tp-subgroup all-reduce found to mutate");
    pair.dist.validate().unwrap(); // still well-formed groups
    let report = session().verify(&pair).unwrap();
    assert!(!report.verified(), "wrong-group collective must not verify");
    assert!(!numerical_verify(&pair, 2, 1e-4, 29).equivalent);
}

#[test]
fn indivisible_shard_is_model_spec_error() {
    let base = matmul_base();
    let plan = ParallelPlan::new(Parallelism::Tensor { tp: 3 }).shard("w", 1);
    let err = apply(&base, &plan).unwrap_err();
    assert!(matches!(err, crate::error::ScalifyError::ModelSpec(_)), "{err}");
}

#[test]
fn flash_decoding_plans_are_rejected() {
    let base = matmul_base();
    let plan = ParallelPlan::new(Parallelism::FlashDecoding { tp: 2 });
    assert!(apply(&base, &plan).is_err());
}

/// Two tagged layers for the pipeline tests.
fn layered_base() -> Graph {
    let mut b = GraphBuilder::new("pipe_base", 1);
    b.at("model.py", 5).in_func("model_fwd").layer(None);
    let x = b.parameter("x", f32s(&[4, 8]));
    b.layer(Some(0)).at("decoder.py", 20).in_func("decoder_layer");
    let w0 = b.parameter("w0", f32s(&[8, 8]));
    let h0 = b.matmul(x, w0);
    let a0 = b.tanh(h0);
    b.layer(Some(1)).at("decoder.py", 20).in_func("decoder_layer");
    let w1 = b.parameter("w1", f32s(&[8, 8]));
    let h1 = b.matmul(a0, w1);
    let a1 = b.tanh(h1);
    b.layer(None);
    b.output(a1);
    b.finish()
}

#[test]
fn pipeline_split_inserts_boundary_pair_and_verifies() {
    let base = layered_base();
    let pair = apply(&base, &ParallelPlan::new(Parallelism::Pipeline { pp: 2 })).unwrap();
    pair.dist.validate().unwrap();
    assert_eq!(pair.dist.num_cores, 2);
    let sends = pair.dist.nodes.iter().filter(|n| n.op.name() == "send").count();
    let recvs = pair.dist.nodes.iter().filter(|n| n.op.name() == "recv").count();
    assert_eq!((sends, recvs), (1, 1), "one boundary between two stages");
    // stage ownership recorded
    let stages: Vec<Option<u32>> = pair.dist.nodes.iter().map(|n| n.meta.stage).collect();
    assert!(stages.contains(&Some(0)) && stages.contains(&Some(1)));
    let report = session().verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
    assert!(report.layers.iter().any(|l| l.stage == Some(1)));
    assert!(numerical_verify(&pair, 2, 1e-4, 13).equivalent);
}

#[test]
fn pipeline_degree_must_fit_layers() {
    let base = layered_base();
    let err = apply(&base, &ParallelPlan::new(Parallelism::Pipeline { pp: 3 })).unwrap_err();
    assert!(err.message().contains("exceeds"), "{err}");
}

#[test]
fn combined_pipeline_tensor_keeps_spmd_width() {
    let base = layered_base();
    let plan = ParallelPlan::new(Parallelism::Combined { pp: 2, tp: 2 })
        .shard("w0", 1)
        .shard("w1", 1);
    let pair = apply(&base, &plan).unwrap();
    // SPMD width is the per-stage tensor degree; stages ride as metadata
    assert_eq!(pair.dist.num_cores, 2);
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "send"));
    assert!(pair.dist.nodes.iter().any(|n| n.op.name() == "all-gather"));
    let report = session().verify(&pair).unwrap();
    assert!(report.verified(), "{:?}", report.verdict);
}

#[test]
fn map_shard_dim_split_and_merge() {
    use super::shard::map_shard_dim;
    // split H → (nh, hd)
    assert_eq!(map_shard_dim(&[6, 8], &[6, 4, 2], 1, 2), Ok(1));
    // merge (nh, hd) → H
    assert_eq!(map_shard_dim(&[6, 4, 2], &[6, 8], 1, 2), Ok(1));
    // 1:1
    assert_eq!(map_shard_dim(&[6, 8], &[6, 8], 0, 2), Ok(0));
    // shard not leading in its group
    assert!(map_shard_dim(&[6, 4, 2], &[6, 8], 2, 2).is_err());
}
