//! Pipeline stage splitting: assign contiguous layer ranges to stages,
//! annotate node ownership ([`crate::ir::Meta::stage`]) and carry every
//! cross-stage value through an explicit [`Op::Send`]/[`Op::Recv`] pair.
//!
//! The result stays one graph (the verifier's unit of work): stages are
//! placement metadata, boundary transfers are identity-semantics ops the
//! relation engine sees through, and the per-layer partition keeps
//! verifying each stage's layers in their own bounded e-graphs.

use super::remap_meta;
use crate::error::{Result, ScalifyError};
use crate::ir::{Graph, NodeId, Op};
use rustc_hash::FxHashMap;

/// Split `g` into `pp` pipeline stages over contiguous layer ranges.
///
/// * Every node tagged with layer `l` is owned by stage
///   `rank(l) * pp / L` (balanced contiguous chunks over the `L` distinct
///   layer tags, in order).
/// * Nodes without a layer tag (entry activations, rotary tables, final
///   epilogue) are stage-less: they are considered resident on every
///   stage and never generate transfers — the framework replicates such
///   tensors to all pipeline ranks.
/// * Each def-use edge crossing stages gets a `send` on the producer's
///   stage and a matching `recv` on the consumer's, one channel per
///   transferred value and destination.
///
/// `num_cores` sets the SPMD width of the result: `pp` for a pure
/// pipeline, or the per-stage tensor degree for combined pipeline×tensor
/// plans.
pub fn stage_split(g: &Graph, pp: u32, num_cores: u32) -> Result<Graph> {
    if pp == 0 {
        return Err(ScalifyError::model_spec("pipeline degree must be >= 1"));
    }
    let mut layers: Vec<u32> = Vec::new();
    for n in &g.nodes {
        if let Some(l) = n.meta.layer {
            if !layers.contains(&l) {
                layers.push(l);
            }
        }
    }
    layers.sort_unstable();
    if (layers.len() as u32) < pp {
        return Err(ScalifyError::model_spec(format!(
            "pipeline degree {pp} exceeds the {} tagged layers",
            layers.len()
        )));
    }
    let stage_of_layer: FxHashMap<u32, u32> = layers
        .iter()
        .enumerate()
        .map(|(i, &l)| (l, (i as u32 * pp) / layers.len() as u32))
        .collect();
    let stage_of = |g: &Graph, id: NodeId| -> Option<u32> {
        g.node(id).meta.layer.and_then(|l| stage_of_layer.get(&l).copied())
    };

    let mut out = Graph::new(g.name.clone(), num_cores);
    out.mesh = g.mesh.clone(); // stage splitting keeps the SPMD mesh
    let mut remap: FxHashMap<NodeId, NodeId> = FxHashMap::default();
    // (producer, destination stage) → recv node carrying the value there
    let mut transfers: FxHashMap<(NodeId, u32), NodeId> = FxHashMap::default();
    let mut next_channel = 0u32;

    for n in &g.nodes {
        let my_stage = stage_of(g, n.id);
        let mut inputs = Vec::with_capacity(n.inputs.len());
        for &src in &n.inputs {
            let src_stage = stage_of(g, src);
            let crossing = match (src_stage, my_stage) {
                (Some(a), Some(b)) => a != b,
                _ => false, // stage-less tensors are resident everywhere
            };
            if !crossing {
                inputs.push(remap[&src]);
                continue;
            }
            let dest = my_stage.expect("crossing implies a destination stage");
            let recv = *transfers.entry((src, dest)).or_insert_with(|| {
                let channel = next_channel;
                next_channel += 1;
                let from = remap[&src];
                let shape = out.node(from).shape.clone();
                // boundary ops inherit the producer's source site and layer
                // (they belong to its slice); ownership differs per side
                let mut send_meta = remap_meta(g, &mut out, &g.node(src).meta);
                send_meta.stage = src_stage;
                let send = out.push(Op::Send { channel }, vec![from], shape.clone(), send_meta);
                let mut recv_meta = send_meta;
                recv_meta.stage = Some(dest);
                out.push(Op::Recv { channel }, vec![send], shape, recv_meta)
            });
            inputs.push(recv);
        }
        let mut meta = remap_meta(g, &mut out, &n.meta);
        meta.stage = my_stage;
        let id = out.push(n.op.clone(), inputs, n.shape.clone(), meta);
        remap.insert(n.id, id);
    }
    out.outputs = g.outputs.iter().map(|o| remap[o]).collect();
    Ok(out)
}
