//! Plan-driven parallelism transform engine.
//!
//! The model zoo used to hand-write every distributed graph next to its
//! baseline — four SPMD variants, each a near-duplicate of the baseline
//! builder with collectives spliced in. This module replaces that
//! duplication with a mechanical derivation: [`apply`] takes a baseline
//! (single-device) [`Graph`] plus a [`ParallelPlan`] and derives the
//! distributed graph, its per-core shapes, its collectives and its input
//! [`Annotation`]s.
//!
//! The engine covers the zoo's production parallelization techniques:
//!
//! * **Tensor parallelism** — column/row-sharded projections; partial
//!   products discharged by `all-reduce` at the first consumer that needs
//!   a replicated value (Megatron-style).
//! * **Sequence parallelism** — the same plan with a token-sharded
//!   residual stream; the engine derives the `all-gather` entering each
//!   attention/MLP section and the `reduce-scatter` discharge for free
//!   from the generic placement rules.
//! * **Expert parallelism** — stacked expert weights sharded along the
//!   expert dim; the baseline's unrolled expert-sum loop collapses to the
//!   core-local terms plus one `all-reduce` (the loop-redistribution
//!   pattern of the paper's Figure 8).
//! * **Pipeline parallelism** — contiguous layer ranges assigned to
//!   stages, boundary values carried by [`Op::Send`]/[`Op::Recv`] pairs,
//!   per-node stage annotations in [`crate::ir::Meta::stage`].
//! * **Data parallelism / ZeRO** — batch-sharded activations; gradient
//!   contractions become per-core partials discharged by `all-reduce`
//!   (ZeRO-0) or `reduce-scatter` against sharded optimizer states
//!   (ZeRO-1/2), with parameter shards gathered on use (stage 2).
//! * **Combined** pipeline × tensor parallelism: the tensor transform per
//!   stage, then stage splitting — the SPMD width stays the per-stage
//!   tensor degree, stages are carried as metadata.
//!
//! The derivation is a single forward pass that assigns every baseline
//! node a *placement* (replicated / sharded / per-core partial / per-core
//! distinct) and emits the distributed node under local shapes, inserting
//! a collective whenever a consumer demands a placement its operand does
//! not have. The hand-built builders remain in the zoo as golden
//! references; the differential tests in [`crate::proptest`] check the
//! engine's output verifies against the baseline *and* agrees numerically
//! with the golden builders.

mod pipeline;
mod shard;

#[cfg(test)]
mod tests;

use crate::error::{Result, ScalifyError};
use crate::ir::{Annotation, Graph};
use crate::modelgen::Parallelism;
use crate::verifier::GraphPair;

pub use pipeline::stage_split;

/// How the plan places one (named) baseline parameter on the mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardRule {
    /// Full replica on every core (the default).
    Replicated,
    /// Split evenly along `dim` across mesh axis `axis` (axis 0 — the
    /// whole mesh — for flat plans).
    Shard {
        /// Baseline dimension that is split.
        dim: usize,
        /// Mesh axis the shard spans.
        axis: usize,
    },
}

/// Source site stamped onto engine-inserted collectives (mirrors the
/// framework function that would emit the collective in a real stack,
/// e.g. `moe.py:84 moe_local`). When absent, inserted collectives inherit
/// the metadata of the value they discharge.
#[derive(Clone, Debug)]
pub struct SiteSpec {
    /// Source file.
    pub file: String,
    /// Source line.
    pub line: u32,
    /// Enclosing framework function.
    pub func: String,
}

/// A parallelization plan: the technique plus the parameter placements.
///
/// Parameter rules match by **name suffix** (first match wins) so one rule
/// covers every layer's instance of a weight (`"q_proj"` matches
/// `l0.q_proj`, `l1.q_proj`, …). Unmatched parameters are replicated.
#[derive(Clone, Debug)]
pub struct ParallelPlan {
    /// Parallelization technique (degree and flavor).
    pub kind: Parallelism,
    /// `(name-suffix, rule)` placement table.
    pub params: Vec<(String, ShardRule)>,
    /// Optional site stamped onto inserted collectives.
    pub collective_site: Option<SiteSpec>,
}

impl ParallelPlan {
    /// Plan with no sharded parameters (everything replicated).
    pub fn new(kind: Parallelism) -> ParallelPlan {
        ParallelPlan { kind, params: Vec::new(), collective_site: None }
    }

    /// Add a shard rule: parameters whose name ends with `suffix` split
    /// along `dim` (over the whole mesh — axis 0).
    pub fn shard(mut self, suffix: &str, dim: usize) -> ParallelPlan {
        self.shard_on(suffix, dim, 0)
    }

    /// Add an axis-scoped shard rule: parameters whose name ends with
    /// `suffix` split along `dim` across mesh axis `axis` only (e.g. the
    /// tp axis of a `[dp, tp]` mesh).
    pub fn shard_on(mut self, suffix: &str, dim: usize, axis: usize) -> ParallelPlan {
        self.params.push((suffix.to_owned(), ShardRule::Shard { dim, axis }));
        self
    }

    /// Pin parameters whose name ends with `suffix` to full replication
    /// (overrides later rules; useful to exempt one tensor from a broad
    /// suffix).
    pub fn replicate(mut self, suffix: &str) -> ParallelPlan {
        self.params.push((suffix.to_owned(), ShardRule::Replicated));
        self
    }

    /// Stamp inserted collectives with a fixed source site.
    pub fn collectives_at(mut self, file: &str, line: u32, func: &str) -> ParallelPlan {
        self.collective_site =
            Some(SiteSpec { file: file.to_owned(), line, func: func.to_owned() });
        self
    }

    /// Placement rule for a parameter name (first matching suffix wins).
    pub fn rule_for(&self, name: &str) -> ShardRule {
        self.params
            .iter()
            .find(|(suffix, _)| name.ends_with(suffix.as_str()))
            .map(|(_, r)| *r)
            .unwrap_or(ShardRule::Replicated)
    }

    /// Shard degree of the SPMD mesh this plan populates (1 for pure
    /// pipeline plans, which replicate rather than shard).
    pub fn shard_degree(&self) -> u32 {
        match self.kind {
            Parallelism::Tensor { tp }
            | Parallelism::Sequence { tp }
            | Parallelism::FlashDecoding { tp } => tp,
            Parallelism::Expert { ep } => ep,
            Parallelism::Data { dp, .. } => dp,
            Parallelism::Pipeline { .. } => 1,
            Parallelism::Combined { tp, .. } => tp,
            Parallelism::Mesh3D { dp, tp, .. } => dp * tp,
        }
    }

    /// SPMD mesh axes of the plan (flat single axis for every pre-mesh
    /// technique; `[dp, tp]` for 3D plans — the pipeline factor is stage
    /// metadata, not an SPMD axis).
    pub fn mesh(&self) -> Vec<u32> {
        match self.kind {
            Parallelism::Mesh3D { dp, tp, .. } => vec![dp, tp],
            _ => vec![self.shard_degree()],
        }
    }
}

/// Derive the distributed graph for `base` under `plan` and pair them.
///
/// The baseline must be a validated single-device graph. Errors are typed
/// [`ScalifyError::ModelSpec`]: indivisible shard dims, placements the
/// engine cannot reconcile, pipeline plans without layer tags, and every
/// other way a plan can fail to apply.
pub fn apply(base: &Graph, plan: &ParallelPlan) -> Result<GraphPair> {
    base.validate().map_err(|e| e.context("transform baseline"))?;
    if base.num_cores != 1 {
        return Err(ScalifyError::model_spec(format!(
            "transform baseline must be single-device, got {} cores",
            base.num_cores
        )));
    }
    if base.nodes.iter().any(|n| n.op.is_collective() || n.op.is_boundary()) {
        return Err(ScalifyError::model_spec(
            "transform baseline already contains collectives or send/recv",
        ));
    }
    match plan.kind {
        Parallelism::FlashDecoding { .. } => Err(ScalifyError::model_spec(
            "flash decoding restructures the softmax and is not plan-derivable; \
             use the hand-built builder (modelgen::llama)",
        )),
        Parallelism::Pipeline { pp } => {
            let dist = stage_split(base, pp, pp)?;
            let annotations = replicated_annotations(base, &dist);
            GraphPair::try_new(base.clone(), dist, annotations)
        }
        Parallelism::Combined { pp, tp } => {
            if tp == 0 || pp == 0 {
                return Err(ScalifyError::model_spec("combined degrees must be >= 1"));
            }
            let (sharded, ann) = shard::shard_transform(base, plan, &[tp])?;
            // the SPMD width stays the per-stage tensor degree; pipeline
            // stages are metadata + send/recv boundaries on top
            let dist = stage_split(&sharded, pp, tp)?;
            let ann = retarget_annotations(&sharded, &dist, ann);
            GraphPair::try_new(base.clone(), dist, ann)
        }
        Parallelism::Mesh3D { pp, dp, tp } => {
            if pp == 0 || dp == 0 || tp == 0 {
                return Err(ScalifyError::model_spec("mesh degrees must be >= 1"));
            }
            // one SPMD graph over the [dp, tp] mesh with subgroup
            // collectives, then pipeline stage splitting as metadata +
            // send/recv on top — the full pp×dp×tp production shape
            let mesh = [dp, tp];
            let (sharded, ann) = shard::shard_transform(base, plan, &mesh)?;
            if pp == 1 {
                GraphPair::try_new(base.clone(), sharded, ann)
            } else {
                let dist = stage_split(&sharded, pp, dp * tp)?;
                let ann = retarget_annotations(&sharded, &dist, ann);
                GraphPair::try_new(base.clone(), dist, ann)
            }
        }
        _ => {
            let degree = plan.shard_degree();
            if degree == 0 {
                return Err(ScalifyError::model_spec("parallelism degree must be >= 1"));
            }
            let (dist, annotations) = shard::shard_transform(base, plan, &[degree])?;
            GraphPair::try_new(base.clone(), dist, annotations)
        }
    }
}

/// Stage splitting re-numbers nodes (send/recv interleave); re-target
/// annotations through the preserved parameter order.
fn retarget_annotations(
    old: &Graph,
    new: &Graph,
    ann: Vec<Annotation>,
) -> Vec<Annotation> {
    let old_params = old.parameters();
    let new_params = new.parameters();
    ann.into_iter()
        .map(|mut a| {
            if let Some(pos) = old_params.iter().position(|&p| p == a.distributed) {
                a.distributed = new_params[pos];
            }
            a
        })
        .collect()
}

/// Positional replicated annotations for a pipeline pair (every parameter
/// of the stage-split graph is the baseline parameter, relocated).
fn replicated_annotations(base: &Graph, dist: &Graph) -> Vec<Annotation> {
    base.parameters()
        .into_iter()
        .zip(dist.parameters())
        .map(|(b, d)| Annotation::replicated(b, d))
        .collect()
}

/// Re-intern a node's metadata into a new graph (thin alias over
/// [`Graph::import_meta`] for the transform builders' call shape).
pub(crate) fn remap_meta(
    src: &Graph,
    dst: &mut Graph,
    meta: &crate::ir::Meta,
) -> crate::ir::Meta {
    dst.import_meta(src, meta)
}

