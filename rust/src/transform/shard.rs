//! The sharding transform: placement propagation + local-shape emission.
//!
//! One forward pass over the baseline assigns every node a [`Placement`]
//! and emits its distributed counterpart under per-core shapes. Collective
//! insertion is demand-driven: when an op combines operands whose
//! placements disagree, the engine *coerces* an operand — `all-reduce` to
//! discharge a partial into a replica, `reduce-scatter` to discharge it
//! into a shard (sequence parallelism, ZeRO), `all-gather` to restore a
//! shard, or a shrunk re-broadcast when the replicated side is free to be
//! born sharded. Coerced variants are memoized per (node, target), so the
//! sequence-parallel `all-gather` feeding q/k/v is emitted once.
//!
//! The expert-parallel unrolled-sum pattern is handled by two extra
//! placements: a slice of a sharded tensor that stays inside the local
//! shard is [`Placement::PerCore`] (per-core *distinct* values), a slice
//! that falls outside is [`Placement::Remote`] and is not emitted at all —
//! an `add` folding a remote term collapses to its local operand and the
//! accumulated local sum becomes a per-core partial, discharged by one
//! `all-reduce` exactly like the hand-built builder.

use super::{remap_meta, ParallelPlan, ShardRule};
use crate::error::{Result, ScalifyError};
use crate::ir::{
    infer_shape, Annotation, Graph, Meta, Node, NodeId, Op, ReduceKind, ReplicaGroups, Shape,
};
use crate::util::Sym;
use rustc_hash::FxHashMap;

macro_rules! spec {
    ($($arg:tt)*) => {
        ScalifyError::model_spec(format!($($arg)*))
    };
}

/// Where a baseline node's value lives on the mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Placement {
    /// Identical full value on every core.
    Rep,
    /// Core `r` holds shard `r` along `dim`.
    Shard {
        /// Sharded baseline dimension.
        dim: usize,
    },
    /// Every core holds a full-shape contribution; cross-core `kind`
    /// reduction yields the baseline value.
    Partial {
        /// Pending reduction.
        kind: ReduceKind,
    },
    /// Per-core distinct values (e.g. each core's local expert slice).
    PerCore,
    /// Owned by other cores' iterations of the same program; not emitted.
    Remote,
}

/// Coercion targets (memo key for emitted variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum Want {
    /// Full replica.
    Rep,
    /// Shard along `dim`.
    Shard(usize),
}

struct Builder<'a> {
    base: &'a Graph,
    plan: &'a ParallelPlan,
    parts: u32,
    out: Graph,
    /// Baseline node → emitted distributed node (None = remote).
    emit: Vec<Option<NodeId>>,
    place: Vec<Placement>,
    /// Coerced variants, memoized per (baseline node, target, consumer
    /// layer). The layer is part of the key so a collective always lives
    /// in the partition group of its consumer — sharing one gather across
    /// layers would desynchronize the baseline/distributed boundary-output
    /// lists the per-layer verification pairs positionally.
    variants: FxHashMap<(NodeId, Want, Option<u32>), NodeId>,
    /// (baseline param, dist param, rule) for the annotation list.
    params: Vec<(NodeId, NodeId, ShardRule)>,
}

/// Apply the sharding plan to `base` over a `parts`-wide mesh.
pub(crate) fn shard_transform(
    base: &Graph,
    plan: &ParallelPlan,
    parts: u32,
) -> Result<(Graph, Vec<Annotation>)> {
    if parts == 1 {
        // degenerate mesh: the distributed graph is the baseline
        let dist = base.clone();
        let ann = base
            .parameters()
            .into_iter()
            .zip(dist.parameters())
            .map(|(b, d)| Annotation::replicated(b, d))
            .collect();
        return Ok((dist, ann));
    }
    let mut b = Builder {
        base,
        plan,
        parts,
        out: Graph::new(format!("{}_dist", base.name.trim_end_matches("_base")), parts),
        emit: vec![None; base.len()],
        place: vec![Placement::Rep; base.len()],
        variants: FxHashMap::default(),
        params: Vec::new(),
    };
    for n in &base.nodes {
        b.visit(n)?;
    }
    for &o in &base.outputs {
        let id = match b.place[o.idx()] {
            Placement::Rep => b.primary(o)?,
            Placement::Shard { .. } | Placement::Partial { .. } => b.coerce(o, Want::Rep, None)?,
            p => {
                return Err(spec!(
                    "graph output {} has non-collectable placement {p:?}",
                    o.0
                ))
            }
        };
        b.out.outputs.push(id);
    }
    let (swept, remap) = sweep(&b.out);
    let annotations = b
        .params
        .iter()
        .map(|&(bid, did, rule)| {
            let did = remap[&did];
            match rule {
                ShardRule::Replicated => Annotation::replicated(bid, did),
                ShardRule::Shard { dim } => Annotation::shard(bid, did, dim, parts),
            }
        })
        .collect();
    Ok((swept, annotations))
}

impl<'a> Builder<'a> {
    /// Emitted id of a baseline node (error when remote).
    fn primary(&self, id: NodeId) -> Result<NodeId> {
        self.emit[id.idx()]
            .ok_or_else(|| spec!("node {} is remote but a local value is required", id.0))
    }

    fn push_node(&mut self, bn: &Node, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let shape = {
            let shapes: Vec<&Shape> =
                inputs.iter().map(|&i| &self.out.node(i).shape).collect();
            infer_shape(&op, &shapes, self.parts)
        };
        let meta = remap_meta(self.base, &mut self.out, &bn.meta);
        self.out.push(op, inputs, shape, meta)
    }

    /// Record emission + placement for a baseline node.
    fn record(&mut self, bn: &Node, id: NodeId, place: Placement) {
        self.emit[bn.id.idx()] = Some(id);
        self.place[bn.id.idx()] = place;
    }

    /// Metadata for an engine-inserted collective discharging `src` on
    /// behalf of a consumer in `layer`.
    fn collective_meta(&mut self, src: NodeId, layer: Option<u32>) -> Meta {
        let m = self.base.node(src).meta;
        let layer = layer.or(m.layer);
        match &self.plan.collective_site {
            Some(site) => Meta {
                file: self.out.interner.intern(&site.file),
                line: site.line,
                expr: Sym::EMPTY,
                func: self.out.interner.intern(&site.func),
                layer,
                stage: m.stage,
            },
            None => {
                let mut meta = remap_meta(self.base, &mut self.out, &m);
                meta.layer = layer;
                meta
            }
        }
    }

    /// True when a replicated variant of `id` was already emitted for any
    /// consumer (used to pick the cheaper side to gather in a dot).
    fn has_rep_variant(&self, id: NodeId) -> bool {
        self.variants.keys().any(|&(n, w, _)| n == id && w == Want::Rep)
    }

    /// Produce (emitting at most one node, memoized per consumer layer)
    /// the `want` variant of baseline node `id`. `layer` is the consuming
    /// node's partition group; inserted collectives join it so the
    /// baseline and distributed layer slices keep positionally-aligned
    /// boundary outputs.
    fn coerce(&mut self, id: NodeId, want: Want, layer: Option<u32>) -> Result<NodeId> {
        let have = self.place[id.idx()];
        match (have, want) {
            (Placement::Rep, Want::Rep) => return self.primary(id),
            (Placement::Shard { dim }, Want::Shard(d)) if dim == d => return self.primary(id),
            _ => {}
        }
        let layer = layer.or_else(|| self.base.node(id).meta.layer);
        if let Some(&v) = self.variants.get(&(id, want, layer)) {
            return Ok(v);
        }
        let full = ReplicaGroups::full(self.parts);
        let src = self.primary(id)?;
        let src_shape = self.out.node(src).shape.clone();
        let built = match (have, want) {
            (Placement::Partial { kind }, Want::Rep) => {
                let meta = self.collective_meta(id, layer);
                self.out.push(
                    Op::AllReduce { kind, groups: full },
                    vec![src],
                    src_shape,
                    meta,
                )
            }
            (Placement::Partial { kind: ReduceKind::Add }, Want::Shard(dim)) => {
                if dim >= src_shape.rank() || src_shape.dims[dim] % self.parts as i64 != 0 {
                    return Err(spec!(
                        "cannot reduce-scatter node {} along dim {dim} across {} cores",
                        id.0,
                        self.parts
                    ));
                }
                let mut dims = src_shape.dims.clone();
                dims[dim] /= self.parts as i64;
                let meta = self.collective_meta(id, layer);
                self.out.push(
                    Op::ReduceScatter { kind: ReduceKind::Add, dim, groups: full },
                    vec![src],
                    src_shape.with_dims(dims),
                    meta,
                )
            }
            (Placement::Shard { dim }, Want::Rep) => {
                let mut dims = src_shape.dims.clone();
                dims[dim] *= self.parts as i64;
                let meta = self.collective_meta(id, layer);
                self.out.push(
                    Op::AllGather { dim, groups: full },
                    vec![src],
                    src_shape.with_dims(dims),
                    meta,
                )
            }
            (Placement::Rep, Want::Shard(dim)) => {
                // a replicated broadcast whose target dim is broadcast-born
                // can be re-emitted sharded at zero communication cost
                let bn = self.base.node(id);
                let Op::Broadcast { mapped, dims } = &bn.op else {
                    return Err(spec!(
                        "cannot shard replicated node {} ({}) along dim {dim}",
                        id.0,
                        bn.op.name()
                    ));
                };
                if mapped.contains(&dim) || dims[dim] % self.parts as i64 != 0 {
                    return Err(spec!(
                        "broadcast {} cannot be born sharded along dim {dim}",
                        id.0
                    ));
                }
                let input = self.primary(bn.inputs[0])?;
                if self.place[bn.inputs[0].idx()] != Placement::Rep {
                    return Err(spec!("broadcast {} input is not replicated", id.0));
                }
                let mut local = dims.clone();
                local[dim] /= self.parts as i64;
                let op = Op::Broadcast { mapped: mapped.clone(), dims: local };
                self.push_node(bn, op, vec![input])
            }
            _ => {
                return Err(spec!(
                    "no coercion from {have:?} to {want:?} for node {}",
                    id.0
                ))
            }
        };
        self.variants.insert((id, want, layer), built);
        Ok(built)
    }

    fn visit(&mut self, bn: &Node) -> Result<()> {
        match &bn.op {
            Op::Parameter { index, name } => {
                let rule = self.plan.rule_for(name);
                let shape = match rule {
                    ShardRule::Replicated => bn.shape.clone(),
                    ShardRule::Shard { dim } => {
                        if dim >= bn.shape.rank()
                            || bn.shape.dims[dim] % self.parts as i64 != 0
                        {
                            return Err(spec!(
                                "parameter '{name}' dim {dim} ({:?}) is not divisible by \
                                 {} shards",
                                bn.shape.dims,
                                self.parts
                            ));
                        }
                        let mut dims = bn.shape.dims.clone();
                        dims[dim] /= self.parts as i64;
                        bn.shape.with_dims(dims)
                    }
                };
                let meta = remap_meta(self.base, &mut self.out, &bn.meta);
                let id = self.out.push(
                    Op::Parameter { index: *index, name: name.clone() },
                    vec![],
                    shape,
                    meta,
                );
                let place = match rule {
                    ShardRule::Replicated => Placement::Rep,
                    ShardRule::Shard { dim } => Placement::Shard { dim },
                };
                self.record(bn, id, place);
                self.params.push((bn.id, id, rule));
                Ok(())
            }
            Op::Constant(_) | Op::Iota { .. } => {
                let meta = remap_meta(self.base, &mut self.out, &bn.meta);
                let id = self.out.push(bn.op.clone(), vec![], bn.shape.clone(), meta);
                self.record(bn, id, Placement::Rep);
                Ok(())
            }
            op if (op.is_elementwise() && bn.inputs.len() == 1)
                || matches!(op, Op::Convert { .. }) =>
            {
                self.visit_unary(bn)
            }
            op if op.is_elementwise() => self.visit_elementwise(bn),
            Op::Dot { .. } => self.visit_dot(bn),
            Op::Reshape { .. } => self.visit_reshape(bn),
            Op::Transpose { .. } => self.visit_transpose(bn),
            Op::Slice { .. } => self.visit_slice(bn),
            Op::Concat { .. } => self.visit_concat(bn),
            Op::Broadcast { .. } => self.visit_broadcast(bn),
            Op::Reduce { .. } => self.visit_reduce(bn),
            Op::Tuple | Op::GetTupleElement { .. } | Op::Custom { .. } => {
                self.visit_opaque(bn)
            }
            _ => Err(spec!(
                "baseline graph contains op '{}' the transform cannot place",
                bn.op.name()
            )),
        }
    }

    fn visit_unary(&mut self, bn: &Node) -> Result<()> {
        let x = bn.inputs[0];
        match self.place[x.idx()] {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Partial { kind }
                if !(matches!(bn.op, Op::Convert { .. })
                    || (bn.op == Op::Neg && kind == ReduceKind::Add)) =>
            {
                // discharge first: only linear ops commute with a pending
                // sum (neg over a Max partial would turn it into a Min),
                // while monotone converts commute with any reduction
                let xv = self.coerce(x, Want::Rep, bn.meta.layer)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::Rep);
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_elementwise(&mut self, bn: &Node) -> Result<()> {
        let lyr = bn.meta.layer;
        let places: Vec<Placement> =
            bn.inputs.iter().map(|i| self.place[i.idx()]).collect();
        // scalar operands broadcast implicitly and never constrain placement
        let neutral: Vec<bool> = bn
            .inputs
            .iter()
            .map(|i| self.base.node(*i).shape.rank() == 0)
            .collect();

        if places.contains(&Placement::Remote) {
            // unrolled-sum collapse: an add folding a remote term takes its
            // local operand's value; the accumulated local sum is a
            // per-core partial of the baseline's full sum
            if bn.op == Op::Add && bn.inputs.len() == 2 {
                let keep = if places[0] == Placement::Remote { 1usize } else { 0 };
                let keep_place = places[keep];
                let other_remote = places[1 - keep] == Placement::Remote;
                let collapsible = matches!(
                    keep_place,
                    Placement::PerCore | Placement::Partial { kind: ReduceKind::Add }
                );
                if other_remote && collapsible {
                    self.emit[bn.id.idx()] = self.emit[bn.inputs[keep].idx()];
                    self.place[bn.id.idx()] =
                        Placement::Partial { kind: ReduceKind::Add };
                    return Ok(());
                }
            }
            // remote operand infects the whole expression (another core's
            // iteration computes it)
            self.place[bn.id.idx()] = Placement::Remote;
            return Ok(());
        }

        if places.iter().any(|p| *p == Placement::PerCore) {
            if !places.iter().all(|p| matches!(p, Placement::PerCore | Placement::Rep)) {
                return Err(spec!(
                    "node {} mixes per-core and sharded operands",
                    bn.id.0
                ));
            }
            let ins = bn
                .inputs
                .iter()
                .map(|&i| self.primary(i))
                .collect::<Result<Vec<_>>>()?;
            self.check_elementwise_dims(bn, &ins, &neutral)?;
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, Placement::PerCore);
            return Ok(());
        }

        // a single shard dim may appear among the operands; everything else
        // is coerced toward it (or, failing that, toward replication)
        let mut shard_dim: Option<usize> = None;
        for (k, p) in places.iter().enumerate() {
            if neutral[k] {
                continue;
            }
            if let Placement::Shard { dim } = p {
                match shard_dim {
                    None => shard_dim = Some(*dim),
                    Some(d) if d == *dim => {}
                    Some(d) => {
                        return Err(spec!(
                            "node {} combines shards along dims {d} and {dim}",
                            bn.id.0
                        ))
                    }
                }
            }
        }
        if let Some(d) = shard_dim {
            if let Some(ins) = self.try_gather_operands(bn, &neutral, Want::Shard(d)) {
                self.check_elementwise_dims(bn, &ins, &neutral)?;
                let id = self.push_node(bn, bn.op.clone(), ins);
                self.record(bn, id, Placement::Shard { dim: d });
                return Ok(());
            }
            // some operand could not be sharded: fall back to replication
            let ins = bn
                .inputs
                .iter()
                .map(|&i| self.coerce(i, Want::Rep, lyr))
                .collect::<Result<Vec<_>>>()?;
            self.check_elementwise_dims(bn, &ins, &neutral)?;
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, Placement::Rep);
            return Ok(());
        }

        let partials: Vec<Option<ReduceKind>> = places
            .iter()
            .map(|p| match p {
                Placement::Partial { kind } => Some(*kind),
                _ => None,
            })
            .collect();
        if partials.iter().any(|p| p.is_some()) {
            // every operand — including implicit-broadcast scalars — must
            // itself be an Add-partial: (Σa) ± (Σb) = Σ(a ± b), but a
            // non-partial term folded into a partial would be summed once
            // per core by the eventual discharge
            let all_add = partials.iter().all(|p| *p == Some(ReduceKind::Add));
            if matches!(bn.op, Op::Add | Op::Sub) && all_add {
                // sums of per-core partials stay partial
                let ins = bn
                    .inputs
                    .iter()
                    .map(|&i| self.primary(i))
                    .collect::<Result<Vec<_>>>()?;
                self.check_elementwise_dims(bn, &ins, &neutral)?;
                let id = self.push_node(bn, bn.op.clone(), ins);
                self.record(bn, id, Placement::Partial { kind: ReduceKind::Add });
                return Ok(());
            }
            let ins = bn
                .inputs
                .iter()
                .map(|&i| self.coerce(i, Want::Rep, lyr))
                .collect::<Result<Vec<_>>>()?;
            self.check_elementwise_dims(bn, &ins, &neutral)?;
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, Placement::Rep);
            return Ok(());
        }

        let ins = bn
            .inputs
            .iter()
            .map(|&i| self.primary(i))
            .collect::<Result<Vec<_>>>()?;
        self.check_elementwise_dims(bn, &ins, &neutral)?;
        let id = self.push_node(bn, bn.op.clone(), ins);
        self.record(bn, id, Placement::Rep);
        Ok(())
    }

    /// Coerce every non-neutral operand to `want`; None when any operand
    /// cannot be coerced (no nodes from failed attempts survive the dead
    /// sweep).
    fn try_gather_operands(
        &mut self,
        bn: &Node,
        neutral: &[bool],
        want: Want,
    ) -> Option<Vec<NodeId>> {
        let mut ins = Vec::with_capacity(bn.inputs.len());
        for (k, &i) in bn.inputs.iter().enumerate() {
            if neutral[k] {
                ins.push(self.primary(i).ok()?);
                continue;
            }
            ins.push(self.coerce(i, want, bn.meta.layer).ok()?);
        }
        Some(ins)
    }

    /// Non-scalar operands of an elementwise op must agree on (local) dims.
    fn check_elementwise_dims(
        &self,
        bn: &Node,
        ins: &[NodeId],
        neutral: &[bool],
    ) -> Result<()> {
        let mut dims: Option<&[i64]> = None;
        for (k, &i) in ins.iter().enumerate() {
            if neutral[k] {
                continue;
            }
            let d = &self.out.node(i).shape.dims;
            match dims {
                None => dims = Some(d),
                Some(prev) if prev == d.as_slice() => {}
                Some(prev) => {
                    return Err(spec!(
                        "node {} operands disagree on local shape ({prev:?} vs {d:?})",
                        bn.id.0
                    ))
                }
            }
        }
        Ok(())
    }

    fn visit_dot(&mut self, bn: &Node) -> Result<()> {
        let Op::Dot { lhs_contract, rhs_contract, lhs_batch, rhs_batch } = &bn.op else {
            unreachable!()
        };
        let (li, ri) = (bn.inputs[0], bn.inputs[1]);
        let (mut lp, mut rp) = (self.place[li.idx()], self.place[ri.idx()]);
        if lp == Placement::Remote || rp == Placement::Remote {
            self.place[bn.id.idx()] = Placement::Remote;
            return Ok(());
        }
        if lp == Placement::PerCore || rp == Placement::PerCore {
            if !matches!(lp, Placement::PerCore | Placement::Rep)
                || !matches!(rp, Placement::PerCore | Placement::Rep)
            {
                return Err(spec!("dot {} mixes per-core and sharded operands", bn.id.0));
            }
            let ins = vec![self.primary(li)?, self.primary(ri)?];
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, Placement::PerCore);
            return Ok(());
        }

        // resolve partials: a dot is bilinear, so one Add-partial operand
        // against a replicated one keeps the partial; anything else is
        // discharged up front
        let mut out_partial: Option<ReduceKind> = None;
        let (mut lid, mut rid) = (self.primary(li)?, self.primary(ri)?);
        match (lp, rp) {
            (Placement::Partial { kind: ReduceKind::Add }, Placement::Rep) => {
                out_partial = Some(ReduceKind::Add);
                lp = Placement::Rep;
            }
            (Placement::Rep, Placement::Partial { kind: ReduceKind::Add }) => {
                out_partial = Some(ReduceKind::Add);
                rp = Placement::Rep;
            }
            _ => {
                if matches!(lp, Placement::Partial { .. }) {
                    lid = self.coerce(li, Want::Rep, bn.meta.layer)?;
                    lp = Placement::Rep;
                }
                if matches!(rp, Placement::Partial { .. }) {
                    rid = self.coerce(ri, Want::Rep, bn.meta.layer)?;
                    rp = Placement::Rep;
                }
            }
        }

        // shard resolution: gather operands until the remaining shards form
        // a supported pattern (matching contraction, matching batch, or a
        // single free dim)
        let result_place = loop {
            let ls = match lp {
                Placement::Shard { dim } => Some(dim),
                _ => None,
            };
            let rs = match rp {
                Placement::Shard { dim } => Some(dim),
                _ => None,
            };
            match (ls, rs) {
                (None, None) => {
                    break match out_partial {
                        Some(kind) => Placement::Partial { kind },
                        None => Placement::Rep,
                    }
                }
                (Some(dl), _) if lhs_contract.contains(&dl) => {
                    let pos = lhs_contract.iter().position(|&x| x == dl).unwrap();
                    let matching =
                        rs.is_some_and(|dr| rhs_contract.get(pos) == Some(&dr));
                    if matching {
                        // contracted shard on both sides: per-core partial
                        // products pending a cross-core sum
                        if !matches!(out_partial, None | Some(ReduceKind::Add)) {
                            return Err(spec!("dot {} mixes partial kinds", bn.id.0));
                        }
                        break Placement::Partial { kind: ReduceKind::Add };
                    }
                    lid = self.coerce(li, Want::Rep, bn.meta.layer)?;
                    lp = Placement::Rep;
                }
                (_, Some(dr)) if rhs_contract.contains(&dr) => {
                    // contract-sharded rhs without a matching lhs shard:
                    // gather it (the ZeRO-2 forward weight gather)
                    rid = self.coerce(ri, Want::Rep, bn.meta.layer)?;
                    rp = Placement::Rep;
                }
                (Some(dl), Some(dr))
                    if lhs_batch.contains(&dl) && rhs_batch.contains(&dr) =>
                {
                    let bl = lhs_batch.iter().position(|&x| x == dl);
                    let br = rhs_batch.iter().position(|&x| x == dr);
                    if bl == br {
                        if out_partial.is_some() {
                            return Err(spec!(
                                "dot {} combines a partial with sharded batches",
                                bn.id.0
                            ));
                        }
                        // batch dims lead the output dims
                        break Placement::Shard { dim: bl.unwrap() };
                    }
                    lid = self.coerce(li, Want::Rep, bn.meta.layer)?;
                    lp = Placement::Rep;
                }
                (Some(dl), None) if lhs_batch.contains(&dl) => {
                    lid = self.coerce(li, Want::Rep, bn.meta.layer)?;
                    lp = Placement::Rep;
                }
                (None, Some(dr)) if rhs_batch.contains(&dr) => {
                    rid = self.coerce(ri, Want::Rep, bn.meta.layer)?;
                    rp = Placement::Rep;
                }
                (Some(_), Some(_)) => {
                    // free shards on both sides: gather one operand. Prefer
                    // the side whose replicated variant already exists (the
                    // ZeRO weight gathered by the forward pass); otherwise
                    // gather the lhs — the sequence-parallel all-gather of
                    // the activations
                    if self.has_rep_variant(ri) && !self.has_rep_variant(li) {
                        rid = self.coerce(ri, Want::Rep, bn.meta.layer)?;
                        rp = Placement::Rep;
                    } else {
                        lid = self.coerce(li, Want::Rep, bn.meta.layer)?;
                        lp = Placement::Rep;
                    }
                }
                (Some(dl), None) => {
                    if out_partial.is_some() {
                        return Err(spec!(
                            "dot {} combines a partial with a sharded operand",
                            bn.id.0
                        ));
                    }
                    break Placement::Shard {
                        dim: free_out_dim(
                            self.base.node(li).shape.rank(),
                            lhs_contract,
                            lhs_batch,
                            dl,
                            lhs_batch.len(),
                            0,
                        )?,
                    };
                }
                (None, Some(dr)) => {
                    if out_partial.is_some() {
                        return Err(spec!(
                            "dot {} combines a partial with a sharded operand",
                            bn.id.0
                        ));
                    }
                    let lhs_rank = self.base.node(li).shape.rank();
                    let n_lhs_free = lhs_rank - lhs_contract.len() - lhs_batch.len();
                    break Placement::Shard {
                        dim: free_out_dim(
                            self.base.node(ri).shape.rank(),
                            rhs_contract,
                            rhs_batch,
                            dr,
                            lhs_batch.len(),
                            n_lhs_free,
                        )?,
                    };
                }
            }
        };
        let id = self.push_node(bn, bn.op.clone(), vec![lid, rid]);
        self.record(bn, id, result_place);
        Ok(())
    }

    fn visit_reshape(&mut self, bn: &Node) -> Result<()> {
        let Op::Reshape { dims } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()] {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Shard { dim } => {
                let old = &self.base.node(x).shape.dims;
                let new_dim = map_shard_dim(old, dims, dim, self.parts as i64)
                    .map_err(|m| spec!("reshape {}: {m}", bn.id.0))?;
                let mut local = dims.clone();
                local[new_dim] /= self.parts as i64;
                let xv = self.primary(x)?;
                let id = self.push_node(bn, Op::Reshape { dims: local }, vec![xv]);
                self.record(bn, id, Placement::Shard { dim: new_dim });
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_transpose(&mut self, bn: &Node) -> Result<()> {
        let Op::Transpose { perm } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()] {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Shard { dim } => {
                let new_dim = perm
                    .iter()
                    .position(|&p| p == dim)
                    .ok_or_else(|| spec!("transpose {} drops the shard dim", bn.id.0))?;
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::Shard { dim: new_dim });
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_slice(&mut self, bn: &Node) -> Result<()> {
        let Op::Slice { starts, limits, strides } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()] {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Partial { .. } => {
                // the verifier's slice rule does not see through partials;
                // discharge first
                let xv = self.coerce(x, Want::Rep, bn.meta.layer)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::Rep);
                Ok(())
            }
            Placement::Shard { dim } => {
                if strides.iter().any(|&s| s != 1) {
                    return Err(spec!("strided slice {} on a sharded tensor", bn.id.0));
                }
                let base_dims = &self.base.node(x).shape.dims;
                let local = base_dims[dim] / self.parts as i64;
                if starts[dim] == 0 && limits[dim] == base_dims[dim] {
                    // full range on the shard dim: pass through locally
                    let mut l = limits.clone();
                    l[dim] = local;
                    self.emit_local_slice(bn, x, starts.clone(), l, Placement::Shard { dim })
                } else if limits[dim] <= local {
                    // stays inside the local shard: each core reads its own
                    // expert/chunk — a per-core distinct value
                    self.emit_local_slice(
                        bn,
                        x,
                        starts.clone(),
                        limits.clone(),
                        Placement::PerCore,
                    )
                } else if starts[dim] >= local {
                    // other cores' iterations cover this range
                    self.place[bn.id.idx()] = Placement::Remote;
                    Ok(())
                } else {
                    Err(spec!(
                        "slice {} straddles the shard boundary (dim {dim}, [{}, {}) \
                         with local extent {local})",
                        bn.id.0,
                        starts[dim],
                        limits[dim]
                    ))
                }
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    /// Emit a localized slice — or alias the input when the local slice is
    /// the identity (keeps the verifier's per-core derivation chain short,
    /// and matches the framework idiom of reshaping the whole local shard).
    fn emit_local_slice(
        &mut self,
        bn: &Node,
        x: NodeId,
        starts: Vec<i64>,
        limits: Vec<i64>,
        place: Placement,
    ) -> Result<()> {
        let xv = self.primary(x)?;
        let local_dims = &self.out.node(xv).shape.dims;
        let identity = starts.iter().all(|&s| s == 0)
            && limits.iter().zip(local_dims).all(|(&l, &d)| l == d);
        if identity {
            self.emit[bn.id.idx()] = Some(xv);
            self.place[bn.id.idx()] = place;
            return Ok(());
        }
        let strides = vec![1i64; starts.len()];
        let id = self.push_node(bn, Op::Slice { starts, limits, strides }, vec![xv]);
        self.record(bn, id, place);
        Ok(())
    }

    fn visit_concat(&mut self, bn: &Node) -> Result<()> {
        let Op::Concat { dim } = bn.op else { unreachable!() };
        let lyr = bn.meta.layer;
        let places: Vec<Placement> =
            bn.inputs.iter().map(|i| self.place[i.idx()]).collect();
        if places.contains(&Placement::Remote) {
            self.place[bn.id.idx()] = Placement::Remote;
            return Ok(());
        }
        let lead = places[0];
        let uniform = places.iter().all(|p| *p == lead);
        let place = if uniform {
            if let Placement::Shard { dim: d } = lead {
                if d == dim {
                    return Err(spec!("concat {} along its shard dim", bn.id.0));
                }
            }
            lead
        } else {
            Placement::Rep
        };
        let ins = if uniform {
            bn.inputs
                .iter()
                .map(|&i| self.primary(i))
                .collect::<Result<Vec<_>>>()?
        } else {
            bn.inputs
                .iter()
                .map(|&i| self.coerce(i, Want::Rep, lyr))
                .collect::<Result<Vec<_>>>()?
        };
        let id = self.push_node(bn, bn.op.clone(), ins);
        self.record(bn, id, place);
        Ok(())
    }

    fn visit_broadcast(&mut self, bn: &Node) -> Result<()> {
        let Op::Broadcast { mapped, dims } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()] {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Shard { dim } => {
                let out_dim = mapped[dim];
                let mut local = dims.clone();
                local[out_dim] /= self.parts as i64;
                let xv = self.primary(x)?;
                let op = Op::Broadcast { mapped: mapped.clone(), dims: local };
                let id = self.push_node(bn, op, vec![xv]);
                self.record(bn, id, Placement::Shard { dim: out_dim });
                Ok(())
            }
            Placement::Partial { kind: ReduceKind::Add } => {
                // broadcast commutes with the pending sum
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::Partial { kind: ReduceKind::Add });
                Ok(())
            }
            Placement::Partial { .. } => {
                let xv = self.coerce(x, Want::Rep, bn.meta.layer)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::Rep);
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_reduce(&mut self, bn: &Node) -> Result<()> {
        let Op::Reduce { kind, dims } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()] {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Partial { kind: pk } => {
                if pk == *kind
                    && matches!(pk, ReduceKind::Add | ReduceKind::Max | ReduceKind::Min)
                {
                    let xv = self.primary(x)?;
                    let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                    self.record(bn, id, Placement::Partial { kind: pk });
                } else {
                    let xv = self.coerce(x, Want::Rep, bn.meta.layer)?;
                    let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                    self.record(bn, id, Placement::Rep);
                }
                Ok(())
            }
            Placement::Shard { dim } => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                if dims.contains(&dim) {
                    // the local reduce covers only this core's shard
                    self.record(bn, id, Placement::Partial { kind: *kind });
                } else {
                    let new_dim = dim - dims.iter().filter(|&&d| d < dim).count();
                    self.record(bn, id, Placement::Shard { dim: new_dim });
                }
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_opaque(&mut self, bn: &Node) -> Result<()> {
        let ok = bn
            .inputs
            .iter()
            .all(|i| self.place[i.idx()] == Placement::Rep);
        if !ok {
            return Err(spec!(
                "opaque op '{}' at {} requires replicated operands",
                bn.op.name(),
                bn.id.0
            ));
        }
        let ins = bn
            .inputs
            .iter()
            .map(|&i| self.primary(i))
            .collect::<Result<Vec<_>>>()?;
        let meta = remap_meta(self.base, &mut self.out, &bn.meta);
        let id = self.out.push(bn.op.clone(), ins, bn.shape.clone(), meta);
        self.record(bn, id, Placement::Rep);
        Ok(())
    }
}

/// Output dim a free operand dim lands on (batch dims, then lhs free, then
/// rhs free).
fn free_out_dim(
    rank: usize,
    contract: &[usize],
    batch: &[usize],
    d: usize,
    n_batch: usize,
    free_offset: usize,
) -> Result<usize> {
    let frees: Vec<usize> = (0..rank)
        .filter(|i| !contract.contains(i) && !batch.contains(i))
        .collect();
    let p = frees
        .iter()
        .position(|&f| f == d)
        .ok_or_else(|| spec!("shard dim {d} is not a free dot dim"))?;
    Ok(n_batch + free_offset + p)
}

/// Map a sharded dim through a reshape by aligning factor groups. The
/// shard must be the leading factor of its group and divide the group's
/// leading output dim.
pub(super) fn map_shard_dim(
    old: &[i64],
    new: &[i64],
    d: usize,
    parts: i64,
) -> std::result::Result<usize, String> {
    let (mut i, mut j) = (0usize, 0usize);
    while i < old.len() && j < new.len() {
        let (gi0, gj0) = (i, j);
        let mut a = old[i];
        i += 1;
        let mut b = new[j];
        j += 1;
        while a != b {
            if a < b {
                if i >= old.len() {
                    return Err("reshape groups do not align".into());
                }
                a *= old[i];
                i += 1;
            } else {
                if j >= new.len() {
                    return Err("reshape groups do not align".into());
                }
                b *= new[j];
                j += 1;
            }
        }
        if (gi0..i).contains(&d) {
            if d != gi0 {
                return Err(format!(
                    "shard dim {d} is not the leading factor of its reshape group"
                ));
            }
            if new[gj0] % parts != 0 {
                return Err(format!(
                    "shard of {parts} parts does not divide target dim {} ({})",
                    gj0, new[gj0]
                ));
            }
            return Ok(gj0);
        }
    }
    Err(format!("shard dim {d} not covered by the reshape"))
}

/// Drop nodes unreachable from the outputs (coercion fallbacks leave dead
/// variants behind). Parameters are always kept so the distributed
/// parameter list mirrors the baseline's. Returns the swept graph and the
/// old→new id map for annotation fixup.
fn sweep(g: &Graph) -> (Graph, FxHashMap<NodeId, NodeId>) {
    let mut live = vec![false; g.len()];
    let mut stack: Vec<NodeId> = g.outputs.clone();
    stack.extend(g.parameters());
    while let Some(id) = stack.pop() {
        if live[id.idx()] {
            continue;
        }
        live[id.idx()] = true;
        stack.extend(g.node(id).inputs.iter().copied());
    }
    let mut out = Graph::new(g.name.clone(), g.num_cores);
    let mut remap: FxHashMap<NodeId, NodeId> = FxHashMap::default();
    for n in &g.nodes {
        if !live[n.id.idx()] {
            continue;
        }
        let inputs: Vec<NodeId> = n.inputs.iter().map(|i| remap[i]).collect();
        let meta = remap_meta(g, &mut out, &n.meta);
        let id = out.push(n.op.clone(), inputs, n.shape.clone(), meta);
        remap.insert(n.id, id);
    }
    out.outputs = g.outputs.iter().map(|o| remap[o]).collect();
    (out, remap)
}
