//! The sharding transform: placement propagation + local-shape emission.
//!
//! One forward pass over the baseline assigns every node a [`Placement`]
//! and emits its distributed counterpart under per-core shapes. Collective
//! insertion is demand-driven: when an op combines operands whose
//! placements disagree, the engine *coerces* an operand — `all-reduce` to
//! discharge a partial into a replica, `reduce-scatter` to discharge it
//! into a shard (sequence parallelism, ZeRO), `all-gather` to restore a
//! shard, or a shrunk re-broadcast when the replicated side is free to be
//! born sharded. Coerced variants are memoized per (node, target), so the
//! sequence-parallel `all-gather` feeding q/k/v is emitted once.
//!
//! Since the mesh generalization, placements are **multi-axis**: a value
//! can be sharded along several tensor dims at once, each spanning a
//! different mesh axis, and simultaneously carry a pending reduction over
//! a subset of axes ([`Spmd`]). The dp×tp training step is the canonical
//! case: an activation batch-sharded over dp and hidden-sharded over tp,
//! whose gradient contraction leaves a dp-partial that a **subgroup**
//! all-reduce (strided dp groups) discharges while the tp shard rides
//! along. Every engine-inserted collective names its concrete
//! [`ReplicaGroups`] via [`Mesh::groups_for`] — full-mesh groups on flat
//! plans, true subgroups on mesh plans.
//!
//! The expert-parallel unrolled-sum pattern is handled by two extra
//! placements: a slice of a sharded tensor that stays inside the local
//! shard is [`Placement::PerCore`] (per-core *distinct* values), a slice
//! that falls outside is [`Placement::Remote`] and is not emitted at all —
//! an `add` folding a remote term collapses to its local operand and the
//! accumulated local sum becomes a per-core partial, discharged by one
//! `all-reduce` exactly like the hand-built builder.

use super::{remap_meta, ParallelPlan, ShardRule};
use crate::error::{Result, ScalifyError};
use crate::ir::{
    infer_shape, Annotation, AxesMask, Graph, Mesh, Meta, Node, NodeId, Op, ReduceKind,
    ReplicaGroups, Shape,
};
use crate::util::Sym;
use rustc_hash::FxHashMap;

macro_rules! spec {
    ($($arg:tt)*) => {
        ScalifyError::model_spec(format!($($arg)*))
    };
}

/// Axis-resolved SPMD placement: shard entries `(baseline dim, mesh axis)`
/// — sorted by dim, axes pairwise distinct — plus an optional pending
/// reduction over `partial_axes` (disjoint from the shard axes).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
struct Spmd {
    /// `(sharded baseline dim, mesh axis)` entries.
    shards: Vec<(usize, u8)>,
    /// Pending cross-core reduction.
    partial: Option<ReduceKind>,
    /// Mesh axes the pending reduction spans.
    partial_axes: AxesMask,
}

impl Spmd {
    fn rep() -> Spmd {
        Spmd::default()
    }

    fn sharded(dim: usize, axis: u8) -> Spmd {
        Spmd { shards: vec![(dim, axis)], partial: None, partial_axes: 0 }
    }

    fn partial(kind: ReduceKind, axes: AxesMask) -> Spmd {
        Spmd { shards: Vec::new(), partial: Some(kind), partial_axes: axes }
    }

    fn is_rep(&self) -> bool {
        self.shards.is_empty() && self.partial.is_none()
    }

    fn normalize(mut self) -> Spmd {
        self.shards.sort_unstable();
        self
    }
}

/// Where a baseline node's value lives on the mesh.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Placement {
    /// Axis-resolved SPMD value (replicated / sharded / partial combos).
    Spmd(Spmd),
    /// Per-core distinct values (e.g. each core's local expert slice).
    PerCore,
    /// Owned by other cores' iterations of the same program; not emitted.
    Remote,
}

impl Placement {
    fn rep() -> Placement {
        Placement::Spmd(Spmd::rep())
    }
}

/// Coercion target (memo key for emitted variants): the desired shard
/// entry set, and whether a pending partial is allowed to survive (`true`
/// only for dot-operand gathers, where the dot itself carries the partial
/// through bilinearity).
type Want = (Vec<(usize, u8)>, bool);

struct Builder<'a> {
    base: &'a Graph,
    plan: &'a ParallelPlan,
    mesh: Mesh,
    out: Graph,
    /// Baseline node → emitted distributed node (None = remote).
    emit: Vec<Option<NodeId>>,
    place: Vec<Placement>,
    /// Coerced variants, memoized per (baseline node, target, consumer
    /// layer). The layer is part of the key so a collective always lives
    /// in the partition group of its consumer — sharing one gather across
    /// layers would desynchronize the baseline/distributed boundary-output
    /// lists the per-layer verification pairs positionally.
    variants: FxHashMap<(NodeId, Want, Option<u32>), NodeId>,
    /// (baseline param, dist param, rule) for the annotation list.
    params: Vec<(NodeId, NodeId, ShardRule)>,
}

/// Apply the sharding plan to `base` over the given mesh axes (a 1-element
/// mesh is the classic flat transform).
pub(crate) fn shard_transform(
    base: &Graph,
    plan: &ParallelPlan,
    mesh_axes: &[u32],
) -> Result<(Graph, Vec<Annotation>)> {
    let mesh = Mesh::new(mesh_axes.to_vec());
    let parts = mesh.total();
    if parts == 1 {
        // degenerate mesh: the distributed graph is the baseline
        let dist = base.clone();
        let ann = base
            .parameters()
            .into_iter()
            .zip(dist.parameters())
            .map(|(b, d)| Annotation::replicated(b, d))
            .collect();
        return Ok((dist, ann));
    }
    for (suffix, rule) in &plan.params {
        if let ShardRule::Shard { axis, .. } = rule {
            if *axis >= mesh.rank() {
                return Err(spec!(
                    "shard rule '{suffix}' names mesh axis {axis} but the mesh has \
                     {} axes",
                    mesh.rank()
                ));
            }
        }
    }
    let mut out = Graph::new(format!("{}_dist", base.name.trim_end_matches("_base")), parts);
    if mesh.rank() > 1 {
        out.mesh = mesh.axes.clone();
    }
    let mut b = Builder {
        base,
        plan,
        mesh,
        out,
        emit: vec![None; base.len()],
        place: vec![Placement::rep(); base.len()],
        variants: FxHashMap::default(),
        params: Vec::new(),
    };
    for n in &base.nodes {
        b.visit(n)?;
    }
    for &o in &base.outputs {
        let id = match b.place[o.idx()].clone() {
            Placement::Spmd(s) if s.is_rep() => b.primary(o)?,
            Placement::Spmd(_) => b.coerce(o, &[], false, None)?,
            p => {
                return Err(spec!(
                    "graph output {} has non-collectable placement {p:?}",
                    o.0
                ))
            }
        };
        b.out.outputs.push(id);
    }
    let mesh = b.mesh.clone();
    let (swept, remap) = sweep(&b.out);
    let annotations = b
        .params
        .iter()
        .map(|&(bid, did, rule)| {
            let did = remap[&did];
            match rule {
                ShardRule::Replicated => Annotation::replicated(bid, did),
                ShardRule::Shard { dim, axis } => {
                    Annotation::shard_on(bid, did, dim, mesh.size(axis), axis)
                }
            }
        })
        .collect();
    Ok((swept, annotations))
}

impl<'a> Builder<'a> {
    /// Replica groups of the masked mesh axes.
    fn groups(&self, axes: AxesMask) -> ReplicaGroups {
        self.mesh.groups_for(axes)
    }

    fn axis_size(&self, axis: u8) -> i64 {
        self.mesh.size(axis as usize) as i64
    }

    /// Emitted id of a baseline node (error when remote).
    fn primary(&self, id: NodeId) -> Result<NodeId> {
        self.emit[id.idx()]
            .ok_or_else(|| spec!("node {} is remote but a local value is required", id.0))
    }

    /// The node's placement as an [`Spmd`] (error for PerCore/Remote).
    fn spmd(&self, id: NodeId) -> Result<Spmd> {
        match &self.place[id.idx()] {
            Placement::Spmd(s) => Ok(s.clone()),
            p => Err(spec!("node {} has non-SPMD placement {p:?}", id.0)),
        }
    }

    fn push_node(&mut self, bn: &Node, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let shape = {
            let shapes: Vec<&Shape> =
                inputs.iter().map(|&i| &self.out.node(i).shape).collect();
            infer_shape(&op, &shapes, self.out.num_cores)
        };
        let meta = remap_meta(self.base, &mut self.out, &bn.meta);
        self.out.push(op, inputs, shape, meta)
    }

    /// Record emission + placement for a baseline node.
    fn record(&mut self, bn: &Node, id: NodeId, place: Placement) {
        self.emit[bn.id.idx()] = Some(id);
        self.place[bn.id.idx()] = place;
    }

    fn record_spmd(&mut self, bn: &Node, id: NodeId, s: Spmd) {
        self.record(bn, id, Placement::Spmd(s.normalize()));
    }

    /// Metadata for an engine-inserted collective discharging `src` on
    /// behalf of a consumer in `layer`.
    fn collective_meta(&mut self, src: NodeId, layer: Option<u32>) -> Meta {
        let m = self.base.node(src).meta;
        let layer = layer.or(m.layer);
        match &self.plan.collective_site {
            Some(site) => Meta {
                file: self.out.interner.intern(&site.file),
                line: site.line,
                expr: Sym::EMPTY,
                func: self.out.interner.intern(&site.func),
                layer,
                stage: m.stage,
            },
            None => {
                let mut meta = remap_meta(self.base, &mut self.out, &m);
                meta.layer = layer;
                meta
            }
        }
    }

    /// True when a replicated variant of `id` was already emitted for any
    /// consumer (used to pick the cheaper side to gather in a dot).
    fn has_rep_variant(&self, id: NodeId) -> bool {
        self.variants
            .keys()
            .any(|(n, (t, _), _)| *n == id && t.is_empty())
    }

    /// Produce (memoized per consumer layer) the variant of baseline node
    /// `id` whose shard entries are exactly `want` and whose pending
    /// partial is discharged (unless `keep_partial`). `layer` is the
    /// consuming node's partition group; inserted collectives join it so
    /// the baseline and distributed layer slices keep positionally-aligned
    /// boundary outputs.
    ///
    /// The coercion plan, in order:
    /// 1. `all-gather` every stale shard entry (in `have`, not in `want`)
    ///    over its axis's subgroups;
    /// 2. discharge a pending Add whose axes equal a single wanted-missing
    ///    entry's axis by `reduce-scatter` (the ZeRO / sequence-parallel
    ///    discharge), else by `all-reduce` over the pending axes' groups;
    /// 3. entries still missing are only creatable communication-free by
    ///    re-emitting a broadcast born sharded.
    fn coerce(
        &mut self,
        id: NodeId,
        want: &[(usize, u8)],
        keep_partial: bool,
        layer: Option<u32>,
    ) -> Result<NodeId> {
        let have = self.spmd(id)?;
        let mut want: Vec<(usize, u8)> = want.to_vec();
        want.sort_unstable();
        if have.shards == want && (have.partial.is_none() || keep_partial) {
            return self.primary(id);
        }
        let layer = layer.or_else(|| self.base.node(id).meta.layer);
        let key = (id, (want.clone(), keep_partial), layer);
        if let Some(&v) = self.variants.get(&key) {
            return Ok(v);
        }

        // communication-free re-emission: a replicated broadcast whose
        // target dims are broadcast-born can be emitted sharded directly
        let built = if have.is_rep() && !want.is_empty() {
            self.born_sharded_broadcast(id, &want)?
        } else {
            let mut cur = self.primary(id)?;
            let mut cur_shards = have.shards.clone();
            let mut partial = have.partial;
            let mut partial_axes = have.partial_axes;

            // 1. gather stale entries (a gather commutes with a pending
            // reduction on disjoint axes, which shard/partial axes are by
            // construction)
            let stale: Vec<(usize, u8)> = cur_shards
                .iter()
                .copied()
                .filter(|e| !want.contains(e))
                .collect();
            for (dim, axis) in stale {
                let src_shape = self.out.node(cur).shape.clone();
                let mut dims = src_shape.dims.clone();
                dims[dim] *= self.axis_size(axis);
                let groups = self.groups(1 << axis);
                let meta = self.collective_meta(id, layer);
                cur = self.out.push(
                    Op::AllGather { dim, groups },
                    vec![cur],
                    src_shape.with_dims(dims),
                    meta,
                );
                cur_shards.retain(|&e| e != (dim, axis));
            }

            // 2. discharge the pending reduction
            let missing: Vec<(usize, u8)> = want
                .iter()
                .copied()
                .filter(|e| !cur_shards.contains(e))
                .collect();
            if let Some(kind) = partial {
                if keep_partial {
                    // carried through by the consumer (dot bilinearity)
                } else if kind == ReduceKind::Add
                    && missing.len() == 1
                    && partial_axes == (1 << missing[0].1)
                {
                    // reduce-scatter: discharge + shard in one collective
                    let (dim, axis) = missing[0];
                    let src_shape = self.out.node(cur).shape.clone();
                    if dim >= src_shape.rank()
                        || src_shape.dims[dim] % self.axis_size(axis) != 0
                    {
                        return Err(spec!(
                            "cannot reduce-scatter node {} along dim {dim} across \
                             mesh axis {axis}",
                            id.0
                        ));
                    }
                    let mut dims = src_shape.dims.clone();
                    dims[dim] /= self.axis_size(axis);
                    let groups = self.groups(1 << axis);
                    let meta = self.collective_meta(id, layer);
                    cur = self.out.push(
                        Op::ReduceScatter { kind: ReduceKind::Add, dim, groups },
                        vec![cur],
                        src_shape.with_dims(dims),
                        meta,
                    );
                    cur_shards.push((dim, axis));
                    partial = None;
                    partial_axes = 0;
                } else {
                    let src_shape = self.out.node(cur).shape.clone();
                    let groups = self.groups(partial_axes);
                    let meta = self.collective_meta(id, layer);
                    cur = self.out.push(
                        Op::AllReduce { kind, groups },
                        vec![cur],
                        src_shape,
                        meta,
                    );
                    partial = None;
                    partial_axes = 0;
                }
            }
            let _ = (partial, partial_axes);

            // 3. anything still missing has no communication that creates
            // it (we never slice by core id)
            let missing: Vec<(usize, u8)> = want
                .iter()
                .copied()
                .filter(|e| !cur_shards.contains(e))
                .collect();
            if !missing.is_empty() {
                return Err(spec!(
                    "no coercion gives node {} shard entries {missing:?}",
                    id.0
                ));
            }
            cur
        };
        self.variants.insert(key, built);
        Ok(built)
    }

    /// Re-emit a replicated broadcast with every `want` dim born sharded
    /// (zero communication). Errors when the node is not a broadcast or a
    /// wanted dim is broadcast-mapped / indivisible.
    fn born_sharded_broadcast(
        &mut self,
        id: NodeId,
        want: &[(usize, u8)],
    ) -> Result<NodeId> {
        let bn = self.base.node(id);
        let Op::Broadcast { mapped, dims } = &bn.op else {
            return Err(spec!(
                "cannot shard replicated node {} ({}) to {want:?}",
                id.0,
                bn.op.name()
            ));
        };
        let input = bn.inputs[0];
        if !matches!(&self.place[input.idx()], Placement::Spmd(s) if s.is_rep()) {
            return Err(spec!("broadcast {} input is not replicated", id.0));
        }
        let mut local = dims.clone();
        for &(dim, axis) in want {
            if mapped.contains(&dim) || local[dim] % self.axis_size(axis) != 0 {
                return Err(spec!(
                    "broadcast {} cannot be born sharded along dim {dim}",
                    id.0
                ));
            }
            local[dim] /= self.axis_size(axis);
        }
        let op = Op::Broadcast { mapped: mapped.clone(), dims: local };
        let input = self.primary(input)?;
        Ok(self.push_node(bn, op, vec![input]))
    }

    fn visit(&mut self, bn: &Node) -> Result<()> {
        match &bn.op {
            Op::Parameter { index, name } => {
                let rule = match self.plan.rule_for(name) {
                    // a size-1 axis shards nothing: treat as replication
                    ShardRule::Shard { axis, .. } if self.axis_size(axis as u8) == 1 => {
                        ShardRule::Replicated
                    }
                    r => r,
                };
                let shape = match rule {
                    ShardRule::Replicated => bn.shape.clone(),
                    ShardRule::Shard { dim, axis } => {
                        let parts = self.axis_size(axis as u8);
                        if dim >= bn.shape.rank() || bn.shape.dims[dim] % parts != 0 {
                            return Err(spec!(
                                "parameter '{name}' dim {dim} ({:?}) is not divisible by \
                                 {parts} shards (mesh axis {axis})",
                                bn.shape.dims
                            ));
                        }
                        let mut dims = bn.shape.dims.clone();
                        dims[dim] /= parts;
                        bn.shape.with_dims(dims)
                    }
                };
                let meta = remap_meta(self.base, &mut self.out, &bn.meta);
                let id = self.out.push(
                    Op::Parameter { index: *index, name: name.clone() },
                    vec![],
                    shape,
                    meta,
                );
                let place = match rule {
                    ShardRule::Replicated => Spmd::rep(),
                    ShardRule::Shard { dim, axis } => Spmd::sharded(dim, axis as u8),
                };
                self.record_spmd(bn, id, place);
                self.params.push((bn.id, id, rule));
                Ok(())
            }
            Op::Constant(_) | Op::Iota { .. } => {
                let meta = remap_meta(self.base, &mut self.out, &bn.meta);
                let id = self.out.push(bn.op.clone(), vec![], bn.shape.clone(), meta);
                self.record_spmd(bn, id, Spmd::rep());
                Ok(())
            }
            op if (op.is_elementwise() && bn.inputs.len() == 1)
                || matches!(op, Op::Convert { .. }) =>
            {
                self.visit_unary(bn)
            }
            op if op.is_elementwise() => self.visit_elementwise(bn),
            Op::Dot { .. } => self.visit_dot(bn),
            Op::Reshape { .. } => self.visit_reshape(bn),
            Op::Transpose { .. } => self.visit_transpose(bn),
            Op::Slice { .. } => self.visit_slice(bn),
            Op::Concat { .. } => self.visit_concat(bn),
            Op::Broadcast { .. } => self.visit_broadcast(bn),
            Op::Reduce { .. } => self.visit_reduce(bn),
            Op::Tuple | Op::GetTupleElement { .. } | Op::Custom { .. } => {
                self.visit_opaque(bn)
            }
            _ => Err(spec!(
                "baseline graph contains op '{}' the transform cannot place",
                bn.op.name()
            )),
        }
    }

    fn visit_unary(&mut self, bn: &Node) -> Result<()> {
        let x = bn.inputs[0];
        match &self.place[x.idx()] {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::PerCore => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::PerCore);
                Ok(())
            }
            Placement::Spmd(s) => {
                let s = s.clone();
                let linear = matches!(bn.op, Op::Convert { .. })
                    || (bn.op == Op::Neg && s.partial == Some(ReduceKind::Add));
                if s.partial.is_some() && !linear {
                    // discharge first, keeping the shard entries: only
                    // linear ops commute with a pending sum (neg over a
                    // Max partial would turn it into a Min), while
                    // monotone converts commute with any reduction
                    let shards = s.shards.clone();
                    let xv = self.coerce(x, &shards, false, bn.meta.layer)?;
                    let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                    self.record_spmd(
                        bn,
                        id,
                        Spmd { shards, partial: None, partial_axes: 0 },
                    );
                } else {
                    let xv = self.primary(x)?;
                    let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                    self.record_spmd(bn, id, s);
                }
                Ok(())
            }
        }
    }

    fn visit_elementwise(&mut self, bn: &Node) -> Result<()> {
        let lyr = bn.meta.layer;
        let places: Vec<Placement> =
            bn.inputs.iter().map(|i| self.place[i.idx()].clone()).collect();
        // scalar operands broadcast implicitly and never constrain placement
        let neutral: Vec<bool> = bn
            .inputs
            .iter()
            .map(|i| self.base.node(*i).shape.rank() == 0)
            .collect();

        if places.contains(&Placement::Remote) {
            // unrolled-sum collapse: an add folding a remote term takes its
            // local operand's value; the accumulated local sum is a
            // per-core partial of the baseline's full sum
            if bn.op == Op::Add && bn.inputs.len() == 2 {
                let keep = if places[0] == Placement::Remote { 1usize } else { 0 };
                let other_remote = places[1 - keep] == Placement::Remote;
                let collapsible = match &places[keep] {
                    Placement::PerCore => true,
                    Placement::Spmd(s) => {
                        s.shards.is_empty() && s.partial == Some(ReduceKind::Add)
                    }
                    _ => false,
                };
                if other_remote && collapsible {
                    self.emit[bn.id.idx()] = self.emit[bn.inputs[keep].idx()];
                    self.place[bn.id.idx()] = Placement::Spmd(Spmd::partial(
                        ReduceKind::Add,
                        self.mesh.full_mask(),
                    ));
                    return Ok(());
                }
            }
            // remote operand infects the whole expression (another core's
            // iteration computes it)
            self.place[bn.id.idx()] = Placement::Remote;
            return Ok(());
        }

        if places.iter().any(|p| *p == Placement::PerCore) {
            let ok = places.iter().all(|p| match p {
                Placement::PerCore => true,
                Placement::Spmd(s) => s.is_rep(),
                _ => false,
            });
            if !ok {
                return Err(spec!(
                    "node {} mixes per-core and sharded operands",
                    bn.id.0
                ));
            }
            let ins = bn
                .inputs
                .iter()
                .map(|&i| self.primary(i))
                .collect::<Result<Vec<_>>>()?;
            self.check_elementwise_dims(bn, &ins, &neutral)?;
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, Placement::PerCore);
            return Ok(());
        }

        let spmds: Vec<Spmd> = bn
            .inputs
            .iter()
            .map(|&i| self.spmd(i))
            .collect::<Result<Vec<_>>>()?;

        // sums of aligned partials stay partial: (Σa) ± (Σb) = Σ(a ± b)
        // — every operand (including implicit-broadcast scalars) must be
        // an Add-partial over the SAME axes with the same shard entries,
        // else a non-partial term would be multiply-counted by the
        // eventual discharge
        let all_add = spmds.iter().all(|s| s.partial == Some(ReduceKind::Add));
        if matches!(bn.op, Op::Add | Op::Sub)
            && all_add
            && spmds.iter().all(|s| {
                s.partial_axes == spmds[0].partial_axes && s.shards == spmds[0].shards
            })
        {
            let ins = bn
                .inputs
                .iter()
                .map(|&i| self.primary(i))
                .collect::<Result<Vec<_>>>()?;
            self.check_elementwise_dims(bn, &ins, &neutral)?;
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record_spmd(bn, id, spmds[0].clone());
            return Ok(());
        }

        // target shard set: union of the non-neutral operands' entries —
        // unless entries conflict (same axis on different dims, or same
        // dim on different axes), which falls back to full replication
        let mut target: Vec<(usize, u8)> = Vec::new();
        let mut conflict = false;
        for (k, s) in spmds.iter().enumerate() {
            if neutral[k] {
                continue;
            }
            for &(dim, axis) in &s.shards {
                match target.iter().find(|&&(d, a)| d == dim || a == axis) {
                    Some(&(d, a)) if d == dim && a == axis => {}
                    Some(_) => conflict = true,
                    None => target.push((dim, axis)),
                }
            }
        }
        if conflict {
            target.clear();
        }
        target.sort_unstable();

        // coerce every operand to the target (discharging partials); on
        // failure fall back to full replication. Scalar (neutral) operands
        // never constrain the shard target but STILL need any pending
        // reduction discharged — consuming a raw scalar partial here would
        // silently fold one core's contribution instead of the sum.
        let gather = |b: &mut Self, tgt: &[(usize, u8)]| -> Result<Vec<NodeId>> {
            bn.inputs
                .iter()
                .enumerate()
                .map(|(k, &i)| {
                    if neutral[k] {
                        match &b.place[i.idx()] {
                            Placement::Spmd(s) if s.partial.is_some() => {
                                b.coerce(i, &[], false, lyr)
                            }
                            _ => b.primary(i),
                        }
                    } else {
                        b.coerce(i, tgt, false, lyr)
                    }
                })
                .collect()
        };
        let (ins, got) = match gather(self, &target) {
            Ok(ins) => (ins, target),
            Err(_) if !target.is_empty() => (gather(self, &[])?, Vec::new()),
            Err(e) => return Err(e),
        };
        self.check_elementwise_dims(bn, &ins, &neutral)?;
        let id = self.push_node(bn, bn.op.clone(), ins);
        self.record_spmd(bn, id, Spmd { shards: got, partial: None, partial_axes: 0 });
        Ok(())
    }

    /// Non-scalar operands of an elementwise op must agree on (local) dims.
    fn check_elementwise_dims(
        &self,
        bn: &Node,
        ins: &[NodeId],
        neutral: &[bool],
    ) -> Result<()> {
        let mut dims: Option<&[i64]> = None;
        for (k, &i) in ins.iter().enumerate() {
            if neutral[k] {
                continue;
            }
            let d = &self.out.node(i).shape.dims;
            match dims {
                None => dims = Some(d),
                Some(prev) if prev == d.as_slice() => {}
                Some(prev) => {
                    return Err(spec!(
                        "node {} operands disagree on local shape ({prev:?} vs {d:?})",
                        bn.id.0
                    ))
                }
            }
        }
        Ok(())
    }

    fn visit_dot(&mut self, bn: &Node) -> Result<()> {
        let Op::Dot { lhs_contract, rhs_contract, lhs_batch, rhs_batch } = &bn.op else {
            unreachable!()
        };
        let (li, ri) = (bn.inputs[0], bn.inputs[1]);
        let (lp, rp) = (self.place[li.idx()].clone(), self.place[ri.idx()].clone());
        if lp == Placement::Remote || rp == Placement::Remote {
            self.place[bn.id.idx()] = Placement::Remote;
            return Ok(());
        }
        if lp == Placement::PerCore || rp == Placement::PerCore {
            let rep_or_percore = |p: &Placement| match p {
                Placement::PerCore => true,
                Placement::Spmd(s) => s.is_rep(),
                _ => false,
            };
            if !rep_or_percore(&lp) || !rep_or_percore(&rp) {
                return Err(spec!("dot {} mixes per-core and sharded operands", bn.id.0));
            }
            let ins = vec![self.primary(li)?, self.primary(ri)?];
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, Placement::PerCore);
            return Ok(());
        }
        let mut l = self.spmd(li)?;
        let mut r = self.spmd(ri)?;
        let lyr = bn.meta.layer;

        // resolve partials: a dot is bilinear, so one Add-partial operand
        // against a non-partial one carries the pending sum through;
        // anything else is discharged up front (keeping shard entries)
        let mut carry: AxesMask = 0;
        match (l.partial, r.partial) {
            (None, None) => {}
            (Some(ReduceKind::Add), None) => {
                carry = l.partial_axes;
            }
            (None, Some(ReduceKind::Add)) => {
                carry = r.partial_axes;
            }
            _ => {
                let ls = l.shards.clone();
                self.coerce(li, &ls, false, lyr)?;
                l.partial = None;
                l.partial_axes = 0;
                let rs = r.shards.clone();
                self.coerce(ri, &rs, false, lyr)?;
                r.partial = None;
                r.partial_axes = 0;
            }
        }
        let keep_l = l.partial.is_some();
        let keep_r = r.partial.is_some();

        // iterative shard resolution: match contracted pairs into pending
        // reductions, pair batch entries, map free entries to output dims;
        // any conflict gathers one entry and retries (each retry removes
        // an entry, so the loop terminates)
        let (out_shards, pend_mask, lid, rid) = 'resolve: loop {
            let mut pend: AxesMask = 0;
            let mut l_work = l.shards.clone();
            let mut r_work = r.shards.clone();
            let mut out_entries: Vec<(usize, u8)> = Vec::new();

            // 1. contracted entries
            let mut k = 0;
            while k < l_work.len() {
                let (dl, ax) = l_work[k];
                if let Some(pos) = lhs_contract.iter().position(|&x| x == dl) {
                    let matched = rhs_contract.get(pos).and_then(|&dr| {
                        r_work.iter().position(|&(d2, a2)| d2 == dr && a2 == ax)
                    });
                    match matched {
                        Some(rk) if (pend | carry) & (1 << ax) == 0 => {
                            // contracted shard on both sides: per-core
                            // partial products pending a subgroup sum
                            pend |= 1 << ax;
                            l_work.remove(k);
                            r_work.remove(rk);
                            continue;
                        }
                        _ => {
                            // contracted without a same-axis partner (or a
                            // double-count): gather the lhs entry (the
                            // post-loop coerce emits the collective)
                            l.shards.retain(|&e| e != (dl, ax));
                            continue 'resolve;
                        }
                    }
                }
                k += 1;
            }
            let mut k = 0;
            while k < r_work.len() {
                let (dr, ax) = r_work[k];
                if rhs_contract.contains(&dr) {
                    // contract-sharded rhs without a matching lhs shard:
                    // gather it (the ZeRO-2 forward weight gather)
                    r.shards.retain(|&e| e != (dr, ax));
                    continue 'resolve;
                }
                k += 1;
            }

            // 2. batch entries pair elementwise at the same batch position
            // on the same axis; the output keeps the shard at that batch
            // dim (batch dims lead the output dims)
            let mut k = 0;
            while k < l_work.len() {
                let (dl, ax) = l_work[k];
                if let Some(pos) = lhs_batch.iter().position(|&x| x == dl) {
                    let matched = rhs_batch.get(pos).and_then(|&dr| {
                        r_work.iter().position(|&(d2, a2)| d2 == dr && a2 == ax)
                    });
                    match matched {
                        Some(rk) => {
                            out_entries.push((pos, ax));
                            l_work.remove(k);
                            r_work.remove(rk);
                            continue;
                        }
                        None => {
                            l.shards.retain(|&e| e != (dl, ax));
                            continue 'resolve;
                        }
                    }
                }
                k += 1;
            }
            let mut k = 0;
            while k < r_work.len() {
                let (dr, ax) = r_work[k];
                if rhs_batch.contains(&dr) {
                    r.shards.retain(|&e| e != (dr, ax));
                    continue 'resolve;
                }
                k += 1;
            }

            // 3. free entries land on their output dims
            let lhs_rank = self.base.node(li).shape.rank();
            let n_lhs_free = lhs_rank - lhs_contract.len() - lhs_batch.len();
            let mut free_entries: Vec<((usize, u8), bool)> = Vec::new(); // (entry, is_lhs)
            for &(dl, ax) in &l_work {
                let d = free_out_dim(lhs_rank, lhs_contract, lhs_batch, dl, lhs_batch.len(), 0)?;
                free_entries.push(((d, ax), true));
            }
            for &(dr, ax) in &r_work {
                let d = free_out_dim(
                    self.base.node(ri).shape.rank(),
                    rhs_contract,
                    rhs_batch,
                    dr,
                    lhs_batch.len(),
                    n_lhs_free,
                )?;
                free_entries.push(((d, ax), false));
            }

            // 4. conflicts: an output axis may appear once, and never
            // inside the pending mask — otherwise gather one entry. Free
            // shards on both sides of the same axis prefer gathering the
            // side whose replicated variant already exists (the ZeRO
            // weight gathered by the forward pass); otherwise the lhs —
            // the sequence-parallel all-gather of the activations.
            let mut used: AxesMask = pend | carry;
            for ei in 0..free_entries.len() {
                let ((_, ax), is_lhs) = free_entries[ei];
                if used & (1 << ax) != 0 {
                    // decide which side to gather
                    let earlier =
                        free_entries[..ei].iter().find(|&&((_, a2), _)| a2 == ax);
                    let gather_lhs = if let Some(&((_, _), other_is_lhs)) = earlier {
                        // axis clash between two free entries
                        if is_lhs != other_is_lhs {
                            // free shards on both sides of one axis:
                            // gather the side without a replicated
                            // variant already in flight
                            !(self.has_rep_variant(ri) && !self.has_rep_variant(li))
                        } else {
                            is_lhs
                        }
                    } else {
                        // clash with the pending/carried mask
                        is_lhs
                    };
                    if gather_lhs {
                        // drop one lhs entry on this axis
                        if let Some(&e) =
                            l.shards.iter().find(|&&(_, a)| a == ax)
                        {
                            l.shards.retain(|&x| x != e);
                            continue 'resolve;
                        }
                    }
                    if let Some(&e) = r.shards.iter().find(|&&(_, a)| a == ax) {
                        r.shards.retain(|&x| x != e);
                        continue 'resolve;
                    }
                    // entry came from the same side twice with no removable
                    // counterpart — gather this very entry's side
                    let side = if is_lhs { &mut l } else { &mut r };
                    if let Some(&e) = side.shards.iter().find(|&&(_, a)| a == ax) {
                        side.shards.retain(|&x| x != e);
                        continue 'resolve;
                    }
                    return Err(spec!("dot {} has an unresolvable shard clash", bn.id.0));
                }
                used |= 1 << ax;
                out_entries.push(free_entries[ei].0);
            }

            // resolved: materialize the operands at their (possibly
            // reduced) shard sets
            let ls = l.shards.clone();
            let rs = r.shards.clone();
            let lid = self.coerce(li, &ls, keep_l, lyr)?;
            let rid = self.coerce(ri, &rs, keep_r, lyr)?;
            break 'resolve (out_entries, pend, lid, rid);
        };

        let id = self.push_node(bn, bn.op.clone(), vec![lid, rid]);
        let mask = pend_mask | carry;
        let place = Spmd {
            shards: out_shards,
            partial: if mask != 0 { Some(ReduceKind::Add) } else { None },
            partial_axes: mask,
        };
        self.record_spmd(bn, id, place);
        Ok(())
    }

    fn visit_reshape(&mut self, bn: &Node) -> Result<()> {
        let Op::Reshape { dims } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()].clone() {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Spmd(s) if !s.shards.is_empty() => {
                let old = &self.base.node(x).shape.dims;
                let mut local = dims.clone();
                let mut new_shards = Vec::with_capacity(s.shards.len());
                for &(dim, axis) in &s.shards {
                    let new_dim =
                        map_shard_dim(old, dims, dim, self.axis_size(axis))
                            .map_err(|m| spec!("reshape {}: {m}", bn.id.0))?;
                    if new_shards.iter().any(|&(d, _)| d == new_dim) {
                        return Err(spec!(
                            "reshape {} folds two shard dims into one group",
                            bn.id.0
                        ));
                    }
                    local[new_dim] /= self.axis_size(axis);
                    new_shards.push((new_dim, axis));
                }
                let xv = self.primary(x)?;
                let id = self.push_node(bn, Op::Reshape { dims: local }, vec![xv]);
                self.record_spmd(
                    bn,
                    id,
                    Spmd { shards: new_shards, partial: s.partial, partial_axes: s.partial_axes },
                );
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_transpose(&mut self, bn: &Node) -> Result<()> {
        let Op::Transpose { perm } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()].clone() {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Spmd(s) if !s.shards.is_empty() => {
                let mut new_shards = Vec::with_capacity(s.shards.len());
                for &(dim, axis) in &s.shards {
                    let new_dim = perm
                        .iter()
                        .position(|&p| p == dim)
                        .ok_or_else(|| spec!("transpose {} drops the shard dim", bn.id.0))?;
                    new_shards.push((new_dim, axis));
                }
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record_spmd(
                    bn,
                    id,
                    Spmd { shards: new_shards, partial: s.partial, partial_axes: s.partial_axes },
                );
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_slice(&mut self, bn: &Node) -> Result<()> {
        let Op::Slice { starts, limits, strides } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()].clone() {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Spmd(s) if s.partial.is_some() => {
                // the verifier's slice rule does not see through partials;
                // discharge first (keeping the shard entries), then slice
                // the discharged variant
                let shards = s.shards.clone();
                let xv = self.coerce(x, &shards, false, bn.meta.layer)?;
                self.slice_sharded(bn, x, xv, &shards, starts, limits, strides)
            }
            Placement::Spmd(s) if !s.shards.is_empty() => {
                if strides.iter().any(|&st| st != 1) {
                    return Err(spec!("strided slice {} on a sharded tensor", bn.id.0));
                }
                let xv = self.primary(x)?;
                self.slice_sharded(bn, x, xv, &s.shards, starts, limits, strides)?;
                Ok(())
            }
            p @ Placement::Spmd(_) => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
            Placement::PerCore => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::PerCore);
                Ok(())
            }
        }
    }

    /// Slice a sharded value: full range on every shard dim passes through
    /// locally; a restricted range on the (single, flat-mesh) shard dim is
    /// the expert-parallel unroll pattern (PerCore / Remote).
    #[allow(clippy::too_many_arguments)]
    fn slice_sharded(
        &mut self,
        bn: &Node,
        x: NodeId,
        xv: NodeId,
        shards: &[(usize, u8)],
        starts: &[i64],
        limits: &[i64],
        strides: &[i64],
    ) -> Result<()> {
        if strides.iter().any(|&st| st != 1) {
            return Err(spec!("strided slice {} on a sharded tensor", bn.id.0));
        }
        let base_dims = &self.base.node(x).shape.dims;
        // which shard dims does the slice restrict?
        let restricted: Vec<(usize, u8)> = shards
            .iter()
            .copied()
            .filter(|&(d, _)| !(starts[d] == 0 && limits[d] == base_dims[d]))
            .collect();
        if restricted.is_empty() {
            // full range on every shard dim: pass through locally
            let mut l = limits.to_vec();
            for &(d, ax) in shards {
                l[d] = base_dims[d] / self.axis_size(ax);
            }
            let place = Spmd {
                shards: shards.to_vec(),
                partial: None,
                partial_axes: 0,
            };
            return self.emit_local_slice(bn, xv, starts.to_vec(), l, Placement::Spmd(place));
        }
        // the expert-unroll pattern: exactly one shard entry spanning the
        // whole (flat) mesh, restricted to one core's range
        if restricted.len() == 1 && shards.len() == 1 && self.mesh.rank() == 1 {
            let (dim, ax) = restricted[0];
            let local = base_dims[dim] / self.axis_size(ax);
            if limits[dim] <= local {
                // stays inside the local shard: each core reads its own
                // expert/chunk — a per-core distinct value
                return self.emit_local_slice(
                    bn,
                    xv,
                    starts.to_vec(),
                    limits.to_vec(),
                    Placement::PerCore,
                );
            } else if starts[dim] >= local {
                // other cores' iterations cover this range
                self.place[bn.id.idx()] = Placement::Remote;
                return Ok(());
            }
            return Err(spec!(
                "slice {} straddles the shard boundary (dim {dim}, [{}, {}) \
                 with local extent {local})",
                bn.id.0,
                starts[dim],
                limits[dim]
            ));
        }
        Err(spec!(
            "slice {} restricts shard dims {restricted:?} (unsupported on this mesh)",
            bn.id.0
        ))
    }

    /// Emit a localized slice — or alias the input when the local slice is
    /// the identity (keeps the verifier's per-core derivation chain short,
    /// and matches the framework idiom of reshaping the whole local shard).
    fn emit_local_slice(
        &mut self,
        bn: &Node,
        xv: NodeId,
        starts: Vec<i64>,
        limits: Vec<i64>,
        place: Placement,
    ) -> Result<()> {
        let local_dims = &self.out.node(xv).shape.dims;
        let identity = starts.iter().all(|&s| s == 0)
            && limits.iter().zip(local_dims).all(|(&l, &d)| l == d);
        if identity {
            self.emit[bn.id.idx()] = Some(xv);
            self.place[bn.id.idx()] = place;
            return Ok(());
        }
        let strides = vec![1i64; starts.len()];
        let id = self.push_node(bn, Op::Slice { starts, limits, strides }, vec![xv]);
        self.record(bn, id, place);
        Ok(())
    }

    fn visit_concat(&mut self, bn: &Node) -> Result<()> {
        let Op::Concat { dim } = bn.op else { unreachable!() };
        let lyr = bn.meta.layer;
        let places: Vec<Placement> =
            bn.inputs.iter().map(|i| self.place[i.idx()].clone()).collect();
        if places.contains(&Placement::Remote) {
            self.place[bn.id.idx()] = Placement::Remote;
            return Ok(());
        }
        let lead = places[0].clone();
        let uniform = places.iter().all(|p| *p == lead);
        if uniform {
            if let Placement::Spmd(s) = &lead {
                if s.shards.iter().any(|&(d, _)| d == dim) {
                    return Err(spec!("concat {} along its shard dim", bn.id.0));
                }
            }
            let ins = bn
                .inputs
                .iter()
                .map(|&i| self.primary(i))
                .collect::<Result<Vec<_>>>()?;
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, lead);
        } else {
            let ins = bn
                .inputs
                .iter()
                .map(|&i| self.coerce(i, &[], false, lyr))
                .collect::<Result<Vec<_>>>()?;
            let id = self.push_node(bn, bn.op.clone(), ins);
            self.record(bn, id, Placement::rep());
        }
        Ok(())
    }

    fn visit_broadcast(&mut self, bn: &Node) -> Result<()> {
        let Op::Broadcast { mapped, dims } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()].clone() {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Spmd(s) if !s.shards.is_empty() => {
                let mut local = dims.clone();
                let mut new_shards = Vec::with_capacity(s.shards.len());
                for &(dim, axis) in &s.shards {
                    let out_dim = mapped[dim];
                    local[out_dim] /= self.axis_size(axis);
                    new_shards.push((out_dim, axis));
                }
                // a pending Add commutes with broadcast; other kinds don't
                let (xv, partial, partial_axes) = if s.partial.is_some()
                    && s.partial != Some(ReduceKind::Add)
                {
                    let shards = s.shards.clone();
                    (self.coerce(x, &shards, false, bn.meta.layer)?, None, 0)
                } else {
                    (self.primary(x)?, s.partial, s.partial_axes)
                };
                let op = Op::Broadcast { mapped: mapped.clone(), dims: local };
                let id = self.push_node(bn, op, vec![xv]);
                self.record_spmd(bn, id, Spmd { shards: new_shards, partial, partial_axes });
                Ok(())
            }
            Placement::Spmd(s) if s.partial == Some(ReduceKind::Add) => {
                // broadcast commutes with the pending sum
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record_spmd(bn, id, s);
                Ok(())
            }
            Placement::Spmd(s) if s.partial.is_some() => {
                let xv = self.coerce(x, &[], false, bn.meta.layer)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, Placement::rep());
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_reduce(&mut self, bn: &Node) -> Result<()> {
        let Op::Reduce { kind, dims } = &bn.op else { unreachable!() };
        let x = bn.inputs[0];
        match self.place[x.idx()].clone() {
            Placement::Remote => {
                self.place[bn.id.idx()] = Placement::Remote;
                Ok(())
            }
            Placement::Spmd(s) => {
                let mut s = s;
                // an incoming partial must match the reduce kind (and be
                // one of the kinds whose local/cross-core order commutes);
                // otherwise discharge first, keeping the shard entries
                let xv;
                match s.partial {
                    Some(pk)
                        if !(pk == *kind
                            && matches!(
                                pk,
                                ReduceKind::Add | ReduceKind::Max | ReduceKind::Min
                            )) =>
                    {
                        let shards = s.shards.clone();
                        xv = self.coerce(x, &shards, false, bn.meta.layer)?;
                        s.partial = None;
                        s.partial_axes = 0;
                    }
                    _ => xv = self.primary(x)?,
                }
                // shard entries on reduced dims become pending reductions
                // over their axes; surviving entries renumber
                let mut pend_axes: AxesMask = 0;
                let mut new_shards: Vec<(usize, u8)> = Vec::new();
                for &(dim, axis) in &s.shards {
                    if dims.contains(&dim) {
                        pend_axes |= 1 << axis;
                    } else {
                        let new_dim = dim - dims.iter().filter(|&&d| d < dim).count();
                        new_shards.push((new_dim, axis));
                    }
                }
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                let partial_axes = s.partial_axes | pend_axes;
                let place = Spmd {
                    shards: new_shards,
                    partial: if partial_axes != 0 { Some(*kind) } else { None },
                    partial_axes,
                };
                self.record_spmd(bn, id, place);
                Ok(())
            }
            p => {
                let xv = self.primary(x)?;
                let id = self.push_node(bn, bn.op.clone(), vec![xv]);
                self.record(bn, id, p);
                Ok(())
            }
        }
    }

    fn visit_opaque(&mut self, bn: &Node) -> Result<()> {
        let ok = bn
            .inputs
            .iter()
            .all(|i| matches!(&self.place[i.idx()], Placement::Spmd(s) if s.is_rep()));
        if !ok {
            return Err(spec!(
                "opaque op '{}' at {} requires replicated operands",
                bn.op.name(),
                bn.id.0
            ));
        }
        let ins = bn
            .inputs
            .iter()
            .map(|&i| self.primary(i))
            .collect::<Result<Vec<_>>>()?;
        let meta = remap_meta(self.base, &mut self.out, &bn.meta);
        let id = self.out.push(bn.op.clone(), ins, bn.shape.clone(), meta);
        self.record(bn, id, Placement::rep());
        Ok(())
    }
}

/// Output dim a free operand dim lands on (batch dims, then lhs free, then
/// rhs free).
fn free_out_dim(
    rank: usize,
    contract: &[usize],
    batch: &[usize],
    d: usize,
    n_batch: usize,
    free_offset: usize,
) -> Result<usize> {
    let frees: Vec<usize> = (0..rank)
        .filter(|i| !contract.contains(i) && !batch.contains(i))
        .collect();
    let p = frees
        .iter()
        .position(|&f| f == d)
        .ok_or_else(|| spec!("shard dim {d} is not a free dot dim"))?;
    Ok(n_batch + free_offset + p)
}

/// Map a sharded dim through a reshape by aligning factor groups. The
/// shard must be the leading factor of its group and divide the group's
/// leading output dim.
pub(super) fn map_shard_dim(
    old: &[i64],
    new: &[i64],
    d: usize,
    parts: i64,
) -> std::result::Result<usize, String> {
    let (mut i, mut j) = (0usize, 0usize);
    while i < old.len() && j < new.len() {
        let (gi0, gj0) = (i, j);
        let mut a = old[i];
        i += 1;
        let mut b = new[j];
        j += 1;
        while a != b {
            if a < b {
                if i >= old.len() {
                    return Err("reshape groups do not align".into());
                }
                a *= old[i];
                i += 1;
            } else {
                if j >= new.len() {
                    return Err("reshape groups do not align".into());
                }
                b *= new[j];
                j += 1;
            }
        }
        if (gi0..i).contains(&d) {
            if d != gi0 {
                return Err(format!(
                    "shard dim {d} is not the leading factor of its reshape group"
                ));
            }
            if new[gj0] % parts != 0 {
                return Err(format!(
                    "shard of {parts} parts does not divide target dim {} ({})",
                    gj0, new[gj0]
                ));
            }
            return Ok(gj0);
        }
    }
    Err(format!("shard dim {d} not covered by the reshape"))
}

/// Drop nodes unreachable from the outputs (coercion fallbacks leave dead
/// variants behind). Parameters are always kept so the distributed
/// parameter list mirrors the baseline's. Returns the swept graph and the
/// old→new id map for annotation fixup.
fn sweep(g: &Graph) -> (Graph, FxHashMap<NodeId, NodeId>) {
    let mut live = vec![false; g.len()];
    let mut stack: Vec<NodeId> = g.outputs.clone();
    stack.extend(g.parameters());
    while let Some(id) = stack.pop() {
        if live[id.idx()] {
            continue;
        }
        live[id.idx()] = true;
        stack.extend(g.node(id).inputs.iter().copied());
    }
    let mut out = Graph::new(g.name.clone(), g.num_cores);
    out.mesh = g.mesh.clone();
    let mut remap: FxHashMap<NodeId, NodeId> = FxHashMap::default();
    for n in &g.nodes {
        if !live[n.id.idx()] {
            continue;
        }
        let inputs: Vec<NodeId> = n.inputs.iter().map(|i| remap[i]).collect();
        let meta = remap_meta(g, &mut out, &n.meta);
        let id = out.push(n.op.clone(), inputs, n.shape.clone(), meta);
        remap.insert(n.id, id);
    }
    out.outputs = g.outputs.iter().map(|o| remap[o]).collect();
    (out, remap)
}
