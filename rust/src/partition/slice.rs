//! Layer slicing: cut a graph along layer boundaries into self-contained
//! subgraphs with explicit boundary inputs/outputs.

use crate::ir::{Graph, Meta, NodeId, Op, Shape};
use rustc_hash::FxHashMap;

/// One layer cut out of a full graph.
#[derive(Debug, Clone)]
pub struct LayerSlice {
    /// Layer index (`u32::MAX` for the no-layer prologue/epilogue group).
    pub layer: u32,
    /// Self-contained subgraph: boundary inputs became parameters.
    pub graph: Graph,
    /// Original node id of each boundary-input parameter (parallel to the
    /// subgraph's parameter order).
    pub ext_inputs: Vec<NodeId>,
    /// Original node ids of the subgraph outputs (values consumed by later
    /// layers or by the full graph's outputs), parallel to `graph.outputs`.
    pub boundary_outputs: Vec<NodeId>,
    /// Parallel to `boundary_outputs`: true when the value is one of the
    /// *full graph's* outputs (those must verify as exact duplicates — a
    /// leftover `partial`/shard there is a genuine divergence).
    pub final_outputs: Vec<bool>,
    /// Mapping original node id → subgraph node id.
    pub node_map: FxHashMap<NodeId, NodeId>,
}

impl LayerSlice {
    /// Pipeline stage owning this layer, if the graph carries stage
    /// annotations (first tagged node wins; stages never split a layer).
    pub fn stage(&self) -> Option<u32> {
        self.graph.nodes.iter().find_map(|n| n.meta.stage)
    }
}

/// Cut `g` into layer slices in layer order.
///
/// Nodes without a layer tag attach to the layer of their (first) consumer
/// group — in practice frameworks tag everything inside a decoder block;
/// untagged nodes (embeddings, final norm) form their own groups at the
/// position they appear.
pub fn extract_layers(g: &Graph) -> Vec<LayerSlice> {
    // group nodes by layer tag, preserving topological position of groups
    let mut order: Vec<u32> = Vec::new();
    let mut groups: FxHashMap<u32, Vec<NodeId>> = FxHashMap::default();
    for n in &g.nodes {
        let tag = n.meta.layer.unwrap_or(u32::MAX);
        if !groups.contains_key(&tag) {
            order.push(tag);
        }
        groups.entry(tag).or_default().push(n.id);
    }
    // The u32::MAX group may interleave before/after real layers; we still
    // emit it as one slice at its first appearance — boundary inputs keep
    // the result correct regardless of emission order relative to uses.
    let uses = g.uses();
    order
        .iter()
        .map(|&tag| build_slice(g, tag, &groups[&tag], &uses))
        .collect()
}

fn build_slice(g: &Graph, tag: u32, members: &[NodeId], uses: &[Vec<NodeId>]) -> LayerSlice {
    let member_set: rustc_hash::FxHashSet<NodeId> = members.iter().copied().collect();
    let mut sub = Graph::new(format!("{}::layer{}", g.name, tag), g.num_cores);
    sub.mesh = g.mesh.clone();
    let mut node_map: FxHashMap<NodeId, NodeId> = FxHashMap::default();
    let mut ext_inputs: Vec<NodeId> = Vec::new();
    let mut next_param = 0usize;

    // walk members in topo order (members are id-sorted = topo)
    for &mid in members {
        let n = g.node(mid);
        // import external operands first
        for &inp in &n.inputs {
            if node_map.contains_key(&inp) {
                continue;
            }
            if member_set.contains(&inp) {
                continue; // will be added in order
            }
            let ext = g.node(inp);
            let sub_id = match &ext.op {
                // constants and iota are cheap: clone them into the slice so
                // boundaries only carry real tensors
                Op::Constant(_) | Op::Iota { .. } => {
                    let meta = remap_meta(g, &mut sub, &ext.meta);
                    sub.push(ext.op.clone(), vec![], ext.shape.clone(), meta)
                }
                _ => {
                    let meta = remap_meta(g, &mut sub, &ext.meta);
                    let name = format!("in{}_{}", next_param, ext.op.name());
                    let id = sub.push(
                        Op::Parameter { index: next_param, name },
                        vec![],
                        ext.shape.clone(),
                        meta,
                    );
                    next_param += 1;
                    ext_inputs.push(inp);
                    id
                }
            };
            node_map.insert(inp, sub_id);
        }
        let inputs: Vec<NodeId> = n.inputs.iter().map(|i| node_map[i]).collect();
        let meta = remap_meta(g, &mut sub, &n.meta);
        // member parameters (layer weights) are boundary inputs too: renumber
        // them into the slice's parameter space and record the original id
        let op = match &n.op {
            Op::Parameter { name, .. } => {
                let idx = next_param;
                next_param += 1;
                ext_inputs.push(mid);
                Op::Parameter { index: idx, name: name.clone() }
            }
            other => other.clone(),
        };
        let sub_id = sub.push(op, inputs, n.shape.clone(), meta);
        node_map.insert(mid, sub_id);
    }

    // boundary outputs: members used outside the layer, or graph outputs
    let mut boundary_outputs = Vec::new();
    let mut final_outputs = Vec::new();
    for &mid in members {
        let is_final = g.outputs.contains(&mid);
        let used_outside =
            uses[mid.idx()].iter().any(|u| !member_set.contains(u)) || is_final;
        if used_outside {
            boundary_outputs.push(mid);
            final_outputs.push(is_final);
            sub.outputs.push(node_map[&mid]);
        }
    }
    LayerSlice { layer: tag, graph: sub, ext_inputs, boundary_outputs, final_outputs, node_map }
}

fn remap_meta(src: &Graph, dst: &mut Graph, meta: &Meta) -> Meta {
    dst.import_meta(src, meta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder};

    fn layered_graph() -> Graph {
        let mut b = GraphBuilder::new("m", 1);
        b.layer(None);
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 8]));
        b.layer(Some(0));
        let w0 = b.parameter("w0", Shape::new(DType::F32, vec![8, 8]));
        let h0 = b.matmul(x, w0);
        let a0 = b.tanh(h0);
        b.layer(Some(1));
        let w1 = b.parameter("w1", Shape::new(DType::F32, vec![8, 8]));
        let h1 = b.matmul(a0, w1);
        let a1 = b.tanh(h1);
        b.layer(None);
        b.output(a1);
        b.finish()
    }

    #[test]
    fn extracts_three_groups() {
        let g = layered_graph();
        let layers = extract_layers(&g);
        assert_eq!(layers.len(), 3); // untagged(x), layer0, layer1
        let l0 = layers.iter().find(|l| l.layer == 0).unwrap();
        // layer0's inputs: the member weight w0 and the boundary value x
        assert_eq!(l0.ext_inputs.len(), 2);
        assert_eq!(l0.boundary_outputs.len(), 1);
        l0.graph.validate().unwrap();
        let l1 = layers.iter().find(|l| l.layer == 1).unwrap();
        assert_eq!(l1.ext_inputs.len(), 2); // w1 and a0 from layer 0
        l1.graph.validate().unwrap();
    }

    #[test]
    fn slice_is_self_contained_and_equivalent() {
        use crate::interp::{run_single, Tensor};
        use crate::util::Prng;
        let g = layered_graph();
        let layers = extract_layers(&g);
        let l0 = layers.iter().find(|l| l.layer == 0).unwrap();
        // run full graph and the slice, compare layer-0 output
        let mut p = Prng::new(9);
        let xv = Tensor::random(Shape::new(DType::F32, vec![4, 8]), &mut p);
        let w0 = Tensor::random(Shape::new(DType::F32, vec![8, 8]), &mut p);
        let w1 = Tensor::random(Shape::new(DType::F32, vec![8, 8]), &mut p);
        let full = run_single(&g, &[xv.clone(), w0.clone(), w1.clone()]).unwrap();
        // slice params: order = [w0 (member param), x (ext)] or [x, w0]
        // depending on construction; resolve by parameter names
        let params = l0.graph.parameters();
        let mut slice_inputs = Vec::new();
        for pid in &params {
            match &l0.graph.node(*pid).op {
                Op::Parameter { name, .. } if name.contains("w0") => {
                    slice_inputs.push(w0.clone())
                }
                _ => slice_inputs.push(xv.clone()),
            }
        }
        let sliced = run_single(&l0.graph, &slice_inputs).unwrap();
        // compose: feed slice output through layer 1 manually
        let l1 = layers.iter().find(|l| l.layer == 1).unwrap();
        let params1 = l1.graph.parameters();
        let mut in1 = Vec::new();
        for pid in &params1 {
            match &l1.graph.node(*pid).op {
                Op::Parameter { name, .. } if name.contains("w1") => in1.push(w1.clone()),
                _ => in1.push(sliced[0].clone()),
            }
        }
        let out1 = run_single(&l1.graph, &in1).unwrap();
        assert!(full[0].max_abs_diff(&out1[0]) < 1e-9);
    }

    #[test]
    fn constants_cloned_not_boundary() {
        let mut b = GraphBuilder::new("m", 1);
        b.layer(None);
        let c = b.constant(2.0, DType::F32);
        b.layer(Some(0));
        let x = b.parameter("x", Shape::new(DType::F32, vec![2]));
        let bc = b.broadcast_scalar(c, vec![2]);
        let y = b.mul(x, bc);
        b.output(y);
        let g = b.finish();
        let layers = extract_layers(&g);
        let l0 = layers.iter().find(|l| l.layer == 0).unwrap();
        // the constant is cloned into the slice; only the member param x
        // is a boundary input
        assert_eq!(l0.ext_inputs.len(), 1);
        assert!(l0
            .graph
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::Constant(_))));
    }

    #[test]
    fn untagged_graph_is_one_slice() {
        let mut b = GraphBuilder::new("m", 1);
        let x = b.parameter("x", Shape::new(DType::F32, vec![2]));
        let y = b.exp(x);
        b.output(y);
        let g = b.finish();
        let layers = extract_layers(&g);
        assert_eq!(layers.len(), 1);
        assert_eq!(layers[0].layer, u32::MAX);
        assert_eq!(layers[0].ext_inputs.len(), 1); // the param x
    }
}
