//! Graph partitioning, layer slicing and fingerprint memoization
//! (paper §5.1, Algorithm 1).
//!
//! Large graphs make whole-graph equality saturation blow up; Scalify cuts
//! the pair along **layer boundaries** (recorded by the framework
//! instrumentation in each node's [`crate::ir::Meta::layer`]), verifies
//! each layer pair in its own bounded e-graph, and **memoizes** layer
//! results by a structural fingerprint so the 126 identical decoder layers
//! of a Llama-405B-style graph are verified once.

mod slice;
pub mod fingerprint;

pub use fingerprint::{
    check_fingerprint_version, fingerprint_pair, fingerprint_slice, LayerMemo,
    MemoEntry, StableHasher, DEFAULT_MEMO_CAPACITY, FINGERPRINT_VERSION,
};
pub use slice::{extract_layers, LayerSlice};
