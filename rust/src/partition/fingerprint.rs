//! Layer fingerprinting + memo table (paper §5.1 "Layer memoization").

use super::LayerSlice;
use crate::verifier::boundary::RelSummary;
use rustc_hash::FxHashMap;
use std::hash::{Hash, Hasher};

/// Structural fingerprint of a (baseline, distributed) layer pair plus its
/// input relations. Two pairs with equal fingerprints verify identically,
/// so the memo replays the first pair's result.
pub fn fingerprint_pair(
    base: &LayerSlice,
    dist: &LayerSlice,
    input_rels: &[(usize, usize, RelSummary)],
    cores: u32,
) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    cores.hash(&mut h);
    hash_slice(base, &mut h);
    hash_slice(dist, &mut h);
    for (bpos, dpos, r) in input_rels {
        bpos.hash(&mut h);
        dpos.hash(&mut h);
        format!("{r:?}").hash(&mut h);
    }
    h.finish()
}

fn hash_slice<H: Hasher>(slice: &LayerSlice, h: &mut H) {
    slice.graph.nodes.len().hash(h);
    for n in &slice.graph.nodes {
        // op identity incl. attributes; Debug formatting is stable within
        // one build and fingerprints never cross process boundaries.
        // Parameters hash by position only — weight *names* differ across
        // otherwise-identical layers (`w0` vs `w1`) and must not defeat
        // memoization.
        match &n.op {
            crate::ir::Op::Parameter { index, .. } => ("param", index).hash(h),
            op => format!("{op:?}").hash(h),
        }
        n.shape.dims.hash(h);
        (n.shape.dtype as u8).hash(h);
        for i in &n.inputs {
            i.0.hash(h);
        }
    }
    for o in &slice.graph.outputs {
        o.0.hash(h);
    }
    // final graph outputs are checked more strictly than interior boundary
    // outputs (exact duplicate vs any propagatable relation), so a final
    // layer must never replay an interior layer's memo entry — this
    // matters doubly now that the memo lives across `Session` runs.
    slice.final_outputs.hash(h);
}

/// Memoized verification result of a layer pair.
#[derive(Clone, Debug)]
pub struct MemoEntry {
    /// Whether the layer pair verified.
    pub verified: bool,
    /// Relation summary of each boundary output pair (propagated to the
    /// next layer per Algorithm 1).
    pub out_rels: Vec<RelSummary>,
    /// How many e-graph nodes the original verification used (stats).
    pub egraph_nodes: usize,
}

/// Fingerprint → result table.
#[derive(Default, Debug)]
pub struct LayerMemo {
    table: FxHashMap<u64, MemoEntry>,
    /// Cache hits served.
    pub hits: usize,
    /// Entries inserted.
    pub misses: usize,
}

impl LayerMemo {
    /// Empty memo.
    pub fn new() -> LayerMemo {
        LayerMemo::default()
    }

    /// Lookup (counts a hit when present).
    pub fn get(&mut self, fp: u64) -> Option<MemoEntry> {
        let entry = self.table.get(&fp).cloned();
        if entry.is_some() {
            self.hits += 1;
        }
        entry
    }

    /// Insert a computed result.
    pub fn put(&mut self, fp: u64, entry: MemoEntry) {
        self.misses += 1;
        self.table.insert(fp, entry);
    }

    /// Peek without counting a hit (used to skip speculative work for
    /// layers the memo can already serve).
    pub fn contains_verified(&self, fp: u64) -> bool {
        self.table.get(&fp).map(|e| e.verified).unwrap_or(false)
    }

    /// Drop all entries (hit/miss counters are kept).
    pub fn clear(&mut self) {
        self.table.clear();
    }

    /// Distinct fingerprints stored.
    pub fn len(&self) -> usize {
        self.table.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, Shape};
    use crate::partition::extract_layers;

    fn identical_layers(n: u32) -> Vec<LayerSlice> {
        let mut b = GraphBuilder::new("m", 1);
        b.layer(None);
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 8]));
        let mut cur = x;
        for l in 0..n {
            b.layer(Some(l));
            let w = b.parameter(&format!("w{l}"), Shape::new(DType::F32, vec![8, 8]));
            let h = b.matmul(cur, w);
            cur = b.tanh(h);
        }
        b.output(cur);
        let g = b.finish();
        extract_layers(&g)
    }

    #[test]
    fn identical_layers_same_fingerprint() {
        let layers = identical_layers(3);
        let l0 = layers.iter().find(|l| l.layer == 0).unwrap();
        let l1 = layers.iter().find(|l| l.layer == 1).unwrap();
        let fp0 = fingerprint_pair(l0, l0, &[], 2);
        let fp1 = fingerprint_pair(l1, l1, &[], 2);
        assert_eq!(fp0, fp1);
        // different input relations change the fingerprint
        let fp2 = fingerprint_pair(l0, l0, &[(0, 0, RelSummary::Duplicate)], 2);
        assert_ne!(fp0, fp2);
        // different core count changes the fingerprint
        let fp3 = fingerprint_pair(l0, l0, &[], 4);
        assert_ne!(fp0, fp3);
    }

    #[test]
    fn final_layer_never_aliases_interior_layers() {
        // the last layer feeds the graph output, and final outputs are
        // checked more strictly (exact duplicate); its fingerprint must
        // differ from a structurally-identical interior layer so a memo
        // replay can't skip that check
        let layers = identical_layers(3);
        let interior = layers.iter().find(|l| l.layer == 1).unwrap();
        let last = layers.iter().find(|l| l.layer == 2).unwrap();
        assert!(last.final_outputs.iter().any(|&f| f));
        assert_ne!(
            fingerprint_pair(interior, interior, &[], 2),
            fingerprint_pair(last, last, &[], 2)
        );
        // but the same final layer re-sliced fingerprints identically
        let again = identical_layers(3);
        let last2 = again.iter().find(|l| l.layer == 2).unwrap();
        assert_eq!(fingerprint_pair(last, last, &[], 2), fingerprint_pair(last2, last2, &[], 2));
    }

    #[test]
    fn memo_hit_miss_counters() {
        let mut memo = LayerMemo::new();
        assert!(memo.get(42).is_none());
        memo.put(42, MemoEntry { verified: true, out_rels: vec![], egraph_nodes: 10 });
        assert!(memo.get(42).is_some());
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.misses, 1);
        assert_eq!(memo.len(), 1);
    }
}
