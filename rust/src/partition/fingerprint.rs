//! Layer fingerprinting + memo table (paper §5.1 "Layer memoization").
//!
//! Fingerprints are **stable across processes**: they are produced by an
//! explicitly-specified FNV-1a hash ([`StableHasher`]) with fixed-width
//! little-endian integer encoding, never by the std `DefaultHasher`
//! (whose keys the std docs reserve the right to randomize). That is what
//! lets the service layer persist memo entries to disk keyed by
//! fingerprint and share them across daemon restarts and CI runs. The
//! encoding of an op still goes through its `Debug` string, which is
//! deterministic for a given source tree — [`FINGERPRINT_VERSION`] must
//! be bumped whenever the hashed structure (op set, attribute layout,
//! field order below) changes, so stale on-disk caches degrade to a cold
//! start instead of replaying entries computed under a different scheme.

use super::LayerSlice;
use crate::verifier::boundary::RelSummary;
use rustc_hash::FxHashMap;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};

/// Version of the fingerprint scheme. Recorded in persistent caches;
/// loading a cache written under a different version is a cold start.
///
/// v2: mesh axes entered the slice hash and `RelSummary` gained mesh-axis
/// fields (subgroup collectives) — v1 entries describe relations under a
/// different encoding and must not replay.
pub const FINGERPRINT_VERSION: u32 = 2;

/// Default [`LayerMemo`] capacity: generous enough that batch runs and
/// week-long daemons over the model zoo never evict in practice, small
/// enough to bound a hostile or pathological workload.
pub const DEFAULT_MEMO_CAPACITY: usize = 65_536;

/// Deterministic 64-bit FNV-1a hasher.
///
/// Unlike `DefaultHasher`, the result is a pure function of the written
/// bytes: no per-process keys, and every integer write is normalized to
/// fixed-width little-endian (the std defaults use native endianness and
/// platform-width `usize`), so the same logical input fingerprints
/// identically on every run, platform and process.
#[derive(Clone, Debug)]
pub struct StableHasher {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl StableHasher {
    /// Hasher at the FNV-1a offset basis.
    pub fn new() -> StableHasher {
        StableHasher { state: FNV_OFFSET }
    }
}

impl Default for StableHasher {
    fn default() -> Self {
        StableHasher::new()
    }
}

impl Hasher for StableHasher {
    fn finish(&self) -> u64 {
        self.state
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= u64::from(b);
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    fn write_u8(&mut self, n: u8) {
        self.write(&[n]);
    }
    fn write_u16(&mut self, n: u16) {
        self.write(&n.to_le_bytes());
    }
    fn write_u32(&mut self, n: u32) {
        self.write(&n.to_le_bytes());
    }
    fn write_u64(&mut self, n: u64) {
        self.write(&n.to_le_bytes());
    }
    fn write_u128(&mut self, n: u128) {
        self.write(&n.to_le_bytes());
    }
    fn write_usize(&mut self, n: usize) {
        // fixed width regardless of platform pointer size
        self.write(&(n as u64).to_le_bytes());
    }
    fn write_i8(&mut self, n: i8) {
        self.write_u8(n as u8);
    }
    fn write_i16(&mut self, n: i16) {
        self.write_u16(n as u16);
    }
    fn write_i32(&mut self, n: i32) {
        self.write_u32(n as u32);
    }
    fn write_i64(&mut self, n: i64) {
        self.write_u64(n as u64);
    }
    fn write_i128(&mut self, n: i128) {
        self.write_u128(n as u128);
    }
    fn write_isize(&mut self, n: isize) {
        self.write_usize(n as usize);
    }
}

/// Structural fingerprint of a (baseline, distributed) layer pair plus its
/// input relations. Two pairs with equal fingerprints verify identically,
/// so the memo replays the first pair's result.
pub fn fingerprint_pair(
    base: &LayerSlice,
    dist: &LayerSlice,
    input_rels: &[(usize, usize, RelSummary)],
    cores: u32,
) -> u64 {
    let mut h = StableHasher::new();
    cores.hash(&mut h);
    hash_slice(base, &mut h);
    hash_slice(dist, &mut h);
    for (bpos, dpos, r) in input_rels {
        bpos.hash(&mut h);
        dpos.hash(&mut h);
        format!("{r:?}").hash(&mut h);
    }
    h.finish()
}

/// Structural fingerprint of a single layer slice (one side of a pair).
/// The diff front end compares these across graph *versions* to find
/// layers that changed even when no node failed to align.
pub fn fingerprint_slice(slice: &LayerSlice) -> u64 {
    let mut h = StableHasher::new();
    hash_slice(slice, &mut h);
    h.finish()
}

/// Validate the `fingerprint_version` field of a persisted document (the
/// service memo cache, the diff `VerifyState`). Every store carrying
/// fingerprints shares this one gate, so version skew degrades to a cold
/// start with the same wording everywhere.
pub fn check_fingerprint_version(
    doc: &crate::report::json::Json,
) -> std::result::Result<(), String> {
    let fpv = doc
        .u64_at("fingerprint_version")
        .ok_or("missing 'fingerprint_version'")?;
    if fpv != FINGERPRINT_VERSION as u64 {
        return Err(format!(
            "fingerprints were computed under scheme v{fpv} (this build uses \
             v{FINGERPRINT_VERSION})"
        ));
    }
    Ok(())
}

fn hash_slice<H: Hasher>(slice: &LayerSlice, h: &mut H) {
    // the declared mesh changes how subgroup collectives verify, so a
    // layer verified under mesh [4] must never replay one under [2,2]
    slice.graph.mesh.hash(h);
    slice.graph.nodes.len().hash(h);
    for n in &slice.graph.nodes {
        // op identity incl. attributes; the Debug string is a pure
        // function of the source tree, and FINGERPRINT_VERSION is bumped
        // whenever it (or anything else hashed here) changes shape.
        // Parameters hash by position only — weight *names* differ across
        // otherwise-identical layers (`w0` vs `w1`) and must not defeat
        // memoization.
        match &n.op {
            crate::ir::Op::Parameter { index, .. } => ("param", index).hash(h),
            op => format!("{op:?}").hash(h),
        }
        n.shape.dims.hash(h);
        (n.shape.dtype as u8).hash(h);
        for i in &n.inputs {
            i.0.hash(h);
        }
    }
    for o in &slice.graph.outputs {
        o.0.hash(h);
    }
    // final graph outputs are checked more strictly than interior boundary
    // outputs (exact duplicate vs any propagatable relation), so a final
    // layer must never replay an interior layer's memo entry — this
    // matters doubly now that the memo lives across `Session` runs and,
    // via the service cache, across processes.
    slice.final_outputs.hash(h);
}

/// Memoized verification result of a layer pair.
#[derive(Clone, Debug, PartialEq)]
pub struct MemoEntry {
    /// Whether the layer pair verified.
    pub verified: bool,
    /// Relation summary of each boundary output pair (propagated to the
    /// next layer per Algorithm 1).
    pub out_rels: Vec<RelSummary>,
    /// How many e-graph nodes the original verification used (stats).
    pub egraph_nodes: usize,
    /// How many e-graph classes the original verification ended with
    /// (stats; 0 in entries persisted before the field existed).
    pub egraph_classes: usize,
}

#[derive(Debug)]
struct Slot {
    entry: MemoEntry,
    /// Recency tick of the last touch; pairs with the lazy markers in
    /// `LayerMemo::recency`.
    tick: u64,
}

/// Fingerprint → result table with bounded capacity and LRU eviction.
///
/// Recency is tracked with lazy-deletion markers: every touch pushes a
/// `(fp, tick)` marker, and eviction pops markers until one matches the
/// slot's current tick (stale markers are skipped). Markers are compacted
/// whenever they outnumber live entries 2:1, so bookkeeping stays linear
/// in the table size.
#[derive(Debug)]
pub struct LayerMemo {
    table: FxHashMap<u64, Slot>,
    recency: VecDeque<(u64, u64)>,
    tick: u64,
    capacity: usize,
    /// Cache hits served.
    pub hits: usize,
    /// Entries inserted after a computed verification.
    pub misses: usize,
    /// Entries evicted to stay within capacity.
    pub evictions: usize,
}

impl Default for LayerMemo {
    fn default() -> Self {
        LayerMemo::with_capacity(DEFAULT_MEMO_CAPACITY)
    }
}

impl LayerMemo {
    /// Empty memo with the [`DEFAULT_MEMO_CAPACITY`].
    pub fn new() -> LayerMemo {
        LayerMemo::default()
    }

    /// Empty memo bounded to `capacity` entries (clamped to at least 1).
    pub fn with_capacity(capacity: usize) -> LayerMemo {
        LayerMemo {
            table: FxHashMap::default(),
            recency: VecDeque::new(),
            tick: 0,
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Maximum entry count before LRU eviction kicks in.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lookup (counts a hit and refreshes recency when present).
    pub fn get(&mut self, fp: u64) -> Option<MemoEntry> {
        let entry = self.table.get(&fp).map(|s| s.entry.clone());
        if entry.is_some() {
            self.hits += 1;
            self.touch(fp);
        }
        entry
    }

    /// Insert a computed result (counts a miss).
    pub fn put(&mut self, fp: u64, entry: MemoEntry) {
        self.misses += 1;
        self.insert(fp, entry);
    }

    /// Insert without counting a miss: warm-start preload from a
    /// persistent store, where the work was done by an earlier process.
    pub fn preload(&mut self, fp: u64, entry: MemoEntry) {
        self.insert(fp, entry);
    }

    fn insert(&mut self, fp: u64, entry: MemoEntry) {
        if !self.table.contains_key(&fp) && self.table.len() >= self.capacity {
            self.evict_lru();
        }
        self.tick += 1;
        let tick = self.tick;
        self.table.insert(fp, Slot { entry, tick });
        self.note(fp, tick);
    }

    fn touch(&mut self, fp: u64) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(slot) = self.table.get_mut(&fp) {
            slot.tick = tick;
        }
        self.note(fp, tick);
    }

    fn note(&mut self, fp: u64, tick: u64) {
        self.recency.push_back((fp, tick));
        if self.recency.len() > 2 * self.table.len() + 64 {
            let table = &self.table;
            self.recency
                .retain(|(f, t)| table.get(f).map(|s| s.tick == *t).unwrap_or(false));
        }
    }

    fn evict_lru(&mut self) {
        while let Some((fp, tick)) = self.recency.pop_front() {
            let live = self.table.get(&fp).map(|s| s.tick == tick).unwrap_or(false);
            if live {
                self.table.remove(&fp);
                self.evictions += 1;
                return;
            }
        }
        // recency markers exhausted (only possible after clear()):
        // fall back to evicting an arbitrary entry
        if let Some(&fp) = self.table.keys().next() {
            self.table.remove(&fp);
            self.evictions += 1;
        }
    }

    /// Peek without counting a hit (used to skip speculative work for
    /// layers the memo can already serve).
    pub fn contains_verified(&self, fp: u64) -> bool {
        self.table.get(&fp).map(|s| s.entry.verified).unwrap_or(false)
    }

    /// Clone a verified entry without counting a hit or refreshing
    /// recency. The parallel scheduling pass uses this to propagate
    /// boundary out-relations for memo-served layers; the sequential
    /// assembly pass performs the counted [`LayerMemo::get`] later, so
    /// hit statistics stay identical to a sequential run.
    pub fn peek_verified(&self, fp: u64) -> Option<MemoEntry> {
        self.table.get(&fp).filter(|s| s.entry.verified).map(|s| s.entry.clone())
    }

    /// Drop all entries (hit/miss/eviction counters are kept).
    pub fn clear(&mut self) {
        self.table.clear();
        self.recency.clear();
    }

    /// Distinct fingerprints stored.
    pub fn len(&self) -> usize {
        self.table.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, GraphBuilder, Shape};
    use crate::partition::extract_layers;

    fn identical_layers(n: u32) -> Vec<LayerSlice> {
        let mut b = GraphBuilder::new("m", 1);
        b.layer(None);
        let x = b.parameter("x", Shape::new(DType::F32, vec![4, 8]));
        let mut cur = x;
        for l in 0..n {
            b.layer(Some(l));
            let w = b.parameter(&format!("w{l}"), Shape::new(DType::F32, vec![8, 8]));
            let h = b.matmul(cur, w);
            cur = b.tanh(h);
        }
        b.output(cur);
        let g = b.finish();
        extract_layers(&g)
    }

    fn entry(nodes: usize) -> MemoEntry {
        MemoEntry { verified: true, out_rels: vec![], egraph_nodes: nodes, egraph_classes: 0 }
    }

    #[test]
    fn stable_hasher_matches_fnv1a_test_vectors() {
        // classic FNV-1a reference values
        let h = StableHasher::new();
        assert_eq!(h.finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = StableHasher::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = StableHasher::new();
        h.write(b"foobar");
        assert_eq!(h.finish(), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn stable_hasher_integer_writes_are_width_normalized() {
        // usize hashes identically to the same value written as u64, so
        // fingerprints agree across pointer widths
        let mut a = StableHasher::new();
        a.write_usize(0x0123_4567);
        let mut b = StableHasher::new();
        b.write_u64(0x0123_4567);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn identical_layers_same_fingerprint() {
        let layers = identical_layers(3);
        let l0 = layers.iter().find(|l| l.layer == 0).unwrap();
        let l1 = layers.iter().find(|l| l.layer == 1).unwrap();
        let fp0 = fingerprint_pair(l0, l0, &[], 2);
        let fp1 = fingerprint_pair(l1, l1, &[], 2);
        assert_eq!(fp0, fp1);
        // different input relations change the fingerprint
        let fp2 = fingerprint_pair(l0, l0, &[(0, 0, RelSummary::Duplicate)], 2);
        assert_ne!(fp0, fp2);
        // different core count changes the fingerprint
        let fp3 = fingerprint_pair(l0, l0, &[], 4);
        assert_ne!(fp0, fp3);
    }

    #[test]
    fn fingerprints_are_reproducible_within_a_process() {
        // same logical input, freshly rebuilt → same fingerprint (the
        // cross-process guarantee is the same computation; this pins the
        // no-randomness part)
        let a = identical_layers(2);
        let b = identical_layers(2);
        let la = a.iter().find(|l| l.layer == 0).unwrap();
        let lb = b.iter().find(|l| l.layer == 0).unwrap();
        assert_eq!(fingerprint_pair(la, la, &[], 4), fingerprint_pair(lb, lb, &[], 4));
    }

    #[test]
    fn final_layer_never_aliases_interior_layers() {
        // the last layer feeds the graph output, and final outputs are
        // checked more strictly (exact duplicate); its fingerprint must
        // differ from a structurally-identical interior layer so a memo
        // replay can't skip that check
        let layers = identical_layers(3);
        let interior = layers.iter().find(|l| l.layer == 1).unwrap();
        let last = layers.iter().find(|l| l.layer == 2).unwrap();
        assert!(last.final_outputs.iter().any(|&f| f));
        assert_ne!(
            fingerprint_pair(interior, interior, &[], 2),
            fingerprint_pair(last, last, &[], 2)
        );
        // but the same final layer re-sliced fingerprints identically
        let again = identical_layers(3);
        let last2 = again.iter().find(|l| l.layer == 2).unwrap();
        assert_eq!(fingerprint_pair(last, last, &[], 2), fingerprint_pair(last2, last2, &[], 2));
    }

    #[test]
    fn memo_hit_miss_counters() {
        let mut memo = LayerMemo::new();
        assert!(memo.get(42).is_none());
        memo.put(42, entry(10));
        assert!(memo.get(42).is_some());
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.misses, 1);
        assert_eq!(memo.len(), 1);
        assert_eq!(memo.evictions, 0);
    }

    #[test]
    fn capacity_bound_evicts_least_recently_used() {
        let mut memo = LayerMemo::with_capacity(3);
        memo.put(1, entry(1));
        memo.put(2, entry(2));
        memo.put(3, entry(3));
        // touch 1 so 2 becomes the LRU
        assert!(memo.get(1).is_some());
        memo.put(4, entry(4));
        assert_eq!(memo.len(), 3);
        assert_eq!(memo.evictions, 1);
        assert!(memo.get(2).is_none(), "LRU entry 2 should have been evicted");
        assert!(memo.get(1).is_some());
        assert!(memo.get(3).is_some());
        assert!(memo.get(4).is_some());
    }

    #[test]
    fn eviction_churn_stays_bounded() {
        let mut memo = LayerMemo::with_capacity(8);
        for i in 0..1000u64 {
            memo.put(i, entry(i as usize));
            // heavy re-touching exercises the lazy-marker compaction
            if i >= 4 {
                let _ = memo.get(i - 4);
            }
        }
        assert_eq!(memo.len(), 8);
        assert_eq!(memo.evictions, 1000 - 8);
        // lazy markers must not grow without bound
        assert!(memo.recency.len() <= 2 * memo.len() + 65, "{}", memo.recency.len());
    }

    #[test]
    fn preload_counts_no_miss() {
        let mut memo = LayerMemo::new();
        memo.preload(7, entry(5));
        assert_eq!(memo.misses, 0);
        assert!(memo.contains_verified(7));
        assert!(memo.get(7).is_some());
        assert_eq!(memo.hits, 1);
    }

    #[test]
    fn reinsert_at_capacity_does_not_evict() {
        let mut memo = LayerMemo::with_capacity(2);
        memo.put(1, entry(1));
        memo.put(2, entry(2));
        // overwrite an existing key: no eviction
        memo.put(1, entry(10));
        assert_eq!(memo.len(), 2);
        assert_eq!(memo.evictions, 0);
        assert_eq!(memo.get(1).unwrap().egraph_nodes, 10);
    }
}
