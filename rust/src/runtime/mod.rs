//! PJRT runtime: load AOT-compiled JAX artifacts and execute them from
//! Rust (the `xla` crate over xla_extension 0.5.1, CPU client).
//!
//! Interchange is HLO **text** — `HloModuleProto::from_text_file` — never
//! serialized protos (jax ≥ 0.5 emits 64-bit instruction ids this XLA
//! rejects). Python runs only at build time; after `make artifacts` the
//! Rust binary is self-contained.

use crate::interp::Tensor;
use crate::ir::{DType, Shape};
use anyhow::{Context, Result};
use std::path::Path;

/// A compiled executable plus its client.
pub struct Executable {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Load HLO text from `path`, compile on the CPU PJRT client.
    pub fn load(path: &Path) -> Result<Executable> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(Executable { client, exe })
    }

    /// Compile HLO text given as a string.
    pub fn from_text(text: &str) -> Result<Executable> {
        let tmp = std::env::temp_dir().join(format!("scalify_hlo_{}.txt", std::process::id()));
        std::fs::write(&tmp, text)?;
        let out = Self::load(&tmp);
        let _ = std::fs::remove_file(&tmp);
        out
    }

    /// Execute with f32 host tensors; returns the tuple elements as host
    /// tensors. Inputs are converted to f32 literals (the artifacts this
    /// repo builds are all-f32 at the interface).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let data: Vec<f32> = t.data.iter().map(|&v| v as f32).collect();
                xla::Literal::vec1(&data)
                    .reshape(&t.shape.dims)
                    .context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // jax lowers with return_tuple=True → outputs are a tuple
        let elements = result.decompose_tuple()?;
        elements
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape()?;
                let dims: Vec<i64> = shape.dims().to_vec();
                let data: Vec<f32> = lit.to_vec::<f32>()?;
                Ok(Tensor::new(
                    Shape::new(DType::F32, dims),
                    data.into_iter().map(|v| v as f64).collect(),
                ))
            })
            .collect()
    }

    /// Device count of the underlying client.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(name: &str) -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name)
    }

    #[test]
    fn executes_jax_artifacts_and_variants_agree() {
        let single = artifact("model_single.hlo.txt");
        let opt = artifact("model_opt.hlo.txt");
        if !single.exists() || !opt.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let exe_a = Executable::load(&single).unwrap();
        let exe_b = Executable::load(&opt).unwrap();
        // shapes from our own parser
        let g = crate::hlo::parse_hlo_file(&single, 1).unwrap();
        let mut p = crate::util::Prng::new(31);
        let inputs: Vec<Tensor> = g
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(g.node(pid).shape.clone(), &mut p))
            .collect();
        let out_a = exe_a.run(&inputs).unwrap();
        let out_b = exe_b.run(&inputs).unwrap();
        assert_eq!(out_a[0].shape.dims, out_b[0].shape.dims);
        let diff = out_a[0].max_abs_diff(&out_b[0]);
        assert!(diff < 1e-4, "variants diverged by {diff}");
    }

    #[test]
    fn buggy_artifact_diverges_numerically() {
        let single = artifact("model_single.hlo.txt");
        let buggy = artifact("model_opt_buggy.hlo.txt");
        if !single.exists() || !buggy.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let exe_a = Executable::load(&single).unwrap();
        let exe_b = Executable::load(&buggy).unwrap();
        let g = crate::hlo::parse_hlo_file(&single, 1).unwrap();
        let mut p = crate::util::Prng::new(33);
        let inputs: Vec<Tensor> = g
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(g.node(pid).shape.clone(), &mut p))
            .collect();
        let out_a = exe_a.run(&inputs).unwrap();
        let out_b = exe_b.run(&inputs).unwrap();
        assert!(out_a[0].max_abs_diff(&out_b[0]) > 1e-3, "BSH bug must change numerics");
    }
}
