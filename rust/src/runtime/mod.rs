//! Execution runtime: load AOT-compiled JAX artifacts (HLO **text**, see
//! `python/compile/aot.py`) and execute them from Rust.
//!
//! The offline build carries no PJRT client, so execution is backed by the
//! crate's own reference interpreter ([`crate::interp`]): artifacts are
//! parsed with the HLO parser and evaluated with per-op dtype quantization,
//! which is exactly what the differential checks need — a verified pair
//! agrees numerically, the BSH-buggy variant diverges. The API mirrors a
//! PJRT-style client (`load` / `run` / `device_count`) so a hardware
//! backend can be slotted in without touching callers.
//!
//! Interchange is HLO **text** — never serialized protos (jax ≥ 0.5 emits
//! 64-bit instruction ids older XLA bindings reject). Python runs only at
//! build time; after `make artifacts` the Rust binary is self-contained.

use crate::error::{Result, ResultExt, ScalifyError};
use crate::interp::Tensor;
use crate::ir::Graph;
use std::path::Path;

/// A loaded executable: the parsed module plus its simulated device mesh.
pub struct Executable {
    graph: Graph,
}

impl Executable {
    /// Load HLO text from `path` (single-core module).
    pub fn load(path: &Path) -> Result<Executable> {
        let graph = crate::hlo::parse_hlo_file(path, 1)
            .with_ctx(|| format!("loading artifact {}", path.display()))?;
        Ok(Executable { graph })
    }

    /// Compile HLO text given as a string.
    pub fn from_text(text: &str) -> Result<Executable> {
        let graph = crate::hlo::parse_hlo_module(text, 1).ctx("loading artifact from text")?;
        Ok(Executable { graph })
    }

    /// Load an SPMD module meant to run at `num_cores`.
    pub fn load_spmd(path: &Path, num_cores: u32) -> Result<Executable> {
        let graph = crate::hlo::parse_hlo_file(path, num_cores)
            .with_ctx(|| format!("loading artifact {}", path.display()))?;
        Ok(Executable { graph })
    }

    /// The parsed module.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Execute with host tensors; returns the output tuple elements.
    ///
    /// Single-core modules evaluate directly; SPMD modules run in lockstep
    /// with the inputs replicated to every core, returning core 0's
    /// outputs.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if self.graph.num_cores <= 1 {
            return crate::interp::run_single(&self.graph, inputs)
                .map_err(|e| ScalifyError::from(e).context("executing artifact"));
        }
        let per_core: Vec<Vec<Tensor>> =
            (0..self.graph.num_cores).map(|_| inputs.to_vec()).collect();
        let mut outs = crate::interp::run_spmd(&self.graph, &per_core)
            .map_err(|e| ScalifyError::from(e).context("executing SPMD artifact"))?;
        Ok(outs.swap_remove(0))
    }

    /// Simulated device count of the loaded module.
    pub fn device_count(&self) -> usize {
        self.graph.num_cores as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(name: &str) -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name)
    }

    #[test]
    fn executes_inline_module() {
        let exe = Executable::from_text(
            r#"
HloModule tiny

ENTRY main {
  x = f32[2,2]{1,0} parameter(0)
  y = f32[2,2]{1,0} parameter(1)
  ROOT s = f32[2,2]{1,0} add(x, y)
}
"#,
        )
        .unwrap();
        let mk = |v: f64| {
            Tensor::new(
                crate::ir::Shape::new(crate::ir::DType::F32, vec![2, 2]),
                vec![v; 4],
            )
        };
        let out = exe.run(&[mk(1.0), mk(2.0)]).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].data.iter().all(|&v| v == 3.0));
        assert_eq!(exe.device_count(), 1);
    }

    #[test]
    fn load_missing_artifact_is_io_error() {
        let err = Executable::load(&artifact("does_not_exist.hlo.txt")).unwrap_err();
        assert!(matches!(err, ScalifyError::Io(_)), "{err}");
    }

    #[test]
    fn executes_jax_artifacts_and_variants_agree() {
        let single = artifact("model_single.hlo.txt");
        let opt = artifact("model_opt.hlo.txt");
        if !single.exists() || !opt.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let exe_a = Executable::load(&single).unwrap();
        let exe_b = Executable::load(&opt).unwrap();
        // shapes from our own parser
        let g = crate::hlo::parse_hlo_file(&single, 1).unwrap();
        let mut p = crate::util::Prng::new(31);
        let inputs: Vec<Tensor> = g
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(g.node(pid).shape.clone(), &mut p))
            .collect();
        let out_a = exe_a.run(&inputs).unwrap();
        let out_b = exe_b.run(&inputs).unwrap();
        assert_eq!(out_a[0].shape.dims, out_b[0].shape.dims);
        let diff = out_a[0].max_abs_diff(&out_b[0]);
        assert!(diff < 1e-4, "variants diverged by {diff}");
    }

    #[test]
    fn buggy_artifact_diverges_numerically() {
        let single = artifact("model_single.hlo.txt");
        let buggy = artifact("model_opt_buggy.hlo.txt");
        if !single.exists() || !buggy.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let exe_a = Executable::load(&single).unwrap();
        let exe_b = Executable::load(&buggy).unwrap();
        let g = crate::hlo::parse_hlo_file(&single, 1).unwrap();
        let mut p = crate::util::Prng::new(33);
        let inputs: Vec<Tensor> = g
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(g.node(pid).shape.clone(), &mut p))
            .collect();
        let out_a = exe_a.run(&inputs).unwrap();
        let out_b = exe_b.run(&inputs).unwrap();
        assert!(out_a[0].max_abs_diff(&out_b[0]) > 1e-3, "BSH bug must change numerics");
    }
}
