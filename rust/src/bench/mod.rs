//! In-repo timing harness (criterion is unavailable offline).
//!
//! Warmup + N samples, reporting mean / median / p95. Used by every
//! `rust/benches/*` target.

use std::time::{Duration, Instant};

/// Timing statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct Stats {
    /// Benchmark label.
    pub label: String,
    /// Samples (sorted).
    pub samples: Vec<Duration>,
}

impl Stats {
    /// Mean sample.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// Median sample.
    pub fn median(&self) -> Duration {
        self.samples[self.samples.len() / 2]
    }

    /// 95th-percentile sample.
    pub fn p95(&self) -> Duration {
        let idx = ((self.samples.len() as f64) * 0.95) as usize;
        self.samples[idx.min(self.samples.len() - 1)]
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} mean {:>10} median {:>10} p95 {:>10} (n={})",
            self.label,
            crate::util::fmt_duration(self.mean()),
            crate::util::fmt_duration(self.median()),
            crate::util::fmt_duration(self.p95()),
            self.samples.len()
        )
    }
}

/// Run `f` with warmup and sampling; returns stats.
pub fn bench<T>(label: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(t0.elapsed());
    }
    out.sort_unstable();
    Stats { label: label.to_string(), samples: out }
}

/// Time a single run (for minutes-scale model verification where one
/// sample is the honest budget).
pub fn time_once<T>(label: &str, f: impl FnOnce() -> T) -> (T, Stats) {
    let t0 = Instant::now();
    let v = f();
    let d = t0.elapsed();
    (v, Stats { label: label.to_string(), samples: vec![d] })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats {
            label: "t".into(),
            samples: vec![
                Duration::from_millis(1),
                Duration::from_millis(2),
                Duration::from_millis(3),
            ],
        };
        assert_eq!(s.mean(), Duration::from_millis(2));
        assert_eq!(s.median(), Duration::from_millis(2));
        assert!(s.summary().contains("n=3"));
    }

    #[test]
    fn bench_runs() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.samples.len(), 5);
    }
}
