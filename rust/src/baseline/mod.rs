//! Comparison baselines (paper §7.1's contrast with TrainVerify and the
//! ad-hoc practice the introduction describes).
//!
//! * [`numerical_verify`] — the practice Scalify replaces: run both graphs
//!   on random inputs and compare activations within a float tolerance.
//!   Fragile (tolerance-sensitive) and cost grows with tensor sizes, while
//!   Scalify is size-independent (Figure 11a/b/e).
//! * [`per_element_verify`] — a TrainVerify-style cost model: equivalence
//!   is checked **per output element**, re-evaluating each element's full
//!   dependency cone (the way per-element symbolic encodings scale). It
//!   returns the same verdicts as the numerical baseline but its runtime
//!   scales with `elements × graph`, reproducing the orders-of-magnitude
//!   gap the paper reports (days vs minutes). It is a *cost-model*
//!   stand-in, not an SMT encoding — see DESIGN.md.

use crate::interp::{run_single, run_spmd, Tensor};
use crate::modelgen::llama::shard_inputs;
use crate::util::Prng;
use crate::verifier::GraphPair;
use std::time::{Duration, Instant};

/// Result of a baseline check.
#[derive(Clone, Debug)]
pub struct BaselineReport {
    /// Equivalent within tolerance on every trial?
    pub equivalent: bool,
    /// Max absolute deviation observed.
    pub max_dev: f64,
    /// Wall time.
    pub duration: Duration,
    /// Trials run.
    pub trials: usize,
}

/// Numerical differential testing: `trials` random-input runs, comparing
/// every core's outputs against the baseline within `tol`.
pub fn numerical_verify(pair: &GraphPair, trials: usize, tol: f64, seed: u64) -> BaselineReport {
    let start = Instant::now();
    let mut prng = Prng::new(seed);
    let mut max_dev = 0.0f64;
    let mut equivalent = true;
    for _ in 0..trials {
        let base_inputs: Vec<Tensor> = pair
            .base
            .parameters()
            .iter()
            .map(|&pid| Tensor::random(pair.base.node(pid).shape.clone(), &mut prng))
            .collect();
        let base_out = match run_single(&pair.base, &base_inputs) {
            Ok(o) => o,
            Err(_) => {
                return BaselineReport {
                    equivalent: false,
                    max_dev: f64::INFINITY,
                    duration: start.elapsed(),
                    trials: 0,
                }
            }
        };
        let dist_out = match shard_inputs(pair, &base_inputs)
            .map_err(|e| e.to_string())
            .and_then(|ins| run_spmd(&pair.dist, &ins).map_err(|e| e.to_string()))
        {
            Ok(o) => o,
            Err(_) => {
                return BaselineReport {
                    equivalent: false,
                    max_dev: f64::INFINITY,
                    duration: start.elapsed(),
                    trials: 0,
                }
            }
        };
        for core_out in &dist_out {
            for (b, d) in base_out.iter().zip(core_out) {
                if b.shape.dims != d.shape.dims {
                    equivalent = false;
                    max_dev = f64::INFINITY;
                    continue;
                }
                let dev = b.max_abs_diff(d);
                max_dev = max_dev.max(dev);
                if dev > tol {
                    equivalent = false;
                }
            }
        }
    }
    BaselineReport { equivalent, max_dev, duration: start.elapsed(), trials }
}

/// TrainVerify-style per-element cost model: evaluates the pair once per
/// output element (bounded by `max_elements` to keep benches tractable;
/// the bench extrapolates total cost from the per-element rate).
pub fn per_element_verify(
    pair: &GraphPair,
    tol: f64,
    seed: u64,
    max_elements: usize,
) -> BaselineReport {
    let start = Instant::now();
    let mut prng = Prng::new(seed);
    let base_inputs: Vec<Tensor> = pair
        .base
        .parameters()
        .iter()
        .map(|&pid| Tensor::random(pair.base.node(pid).shape.clone(), &mut prng))
        .collect();
    let total_elements: i64 = pair
        .base
        .outputs
        .iter()
        .map(|&o| pair.base.node(o).shape.elements())
        .sum();
    let checked = (total_elements as usize).min(max_elements.max(1));
    let mut equivalent = true;
    let mut max_dev = 0.0f64;
    for _elem in 0..checked {
        // per-element reasoning: the whole dependency cone is re-evaluated
        // for every element (no sharing across elements — the cost shape
        // of per-element symbolic encodings)
        let base_out = run_single(&pair.base, &base_inputs).expect("baseline eval");
        let dist_inputs = shard_inputs(pair, &base_inputs).expect("pair annotations");
        let dist_out = run_spmd(&pair.dist, &dist_inputs).expect("dist eval");
        let dev = base_out[0].max_abs_diff(&dist_out[0][0]);
        max_dev = max_dev.max(dev);
        if dev > tol {
            equivalent = false;
        }
    }
    BaselineReport { equivalent, max_dev, duration: start.elapsed(), trials: checked }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelgen::{demo, llama_pair, LlamaConfig, Parallelism};

    #[test]
    fn numerical_accepts_correct_pair() {
        let pair = demo::matmul_allreduce_pair(2);
        let r = numerical_verify(&pair, 3, 1e-4, 7);
        assert!(r.equivalent, "max_dev={}", r.max_dev);
        assert_eq!(r.trials, 3);
    }

    #[test]
    fn numerical_rejects_buggy_pair() {
        let pair = demo::bsh_pair(true);
        let r = numerical_verify(&pair, 2, 1e-4, 7);
        assert!(!r.equivalent);
    }

    #[test]
    fn per_element_is_slower_than_numerical() {
        let pair = llama_pair(&LlamaConfig::tiny(), Parallelism::Tensor { tp: 2 });
        let fast = numerical_verify(&pair, 1, 1e-3, 3);
        let slow = per_element_verify(&pair, 1e-3, 3, 8);
        assert!(fast.equivalent && slow.equivalent);
        // 8 per-element cones vs 1 full evaluation
        assert!(slow.duration > fast.duration, "{:?} vs {:?}", slow.duration, fast.duration);
    }

    #[test]
    fn numerical_misses_tolerance_masked_bugs() {
        // The fragility the paper criticizes: a tiny-precision fault hides
        // below a loose tolerance but is caught by semantic verification.
        let pair = {
            let base = crate::bugs::reproduced_bugs()
                .into_iter()
                .find(|c| c.id == "T4#17")
                .unwrap();
            (base.build)()
        };
        let loose = numerical_verify(&pair, 2, 0.5, 7);
        assert!(loose.equivalent, "loose tolerance masks the bf16 fault");
        let report = crate::verifier::Session::new(crate::verifier::VerifyConfig {
            parallel: false,
            ..Default::default()
        })
        .verify(&pair)
        .unwrap();
        assert!(!report.verified(), "Scalify still catches it");
    }
}
