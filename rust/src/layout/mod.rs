//! Symbolic layout analysis and bijection inference (paper §5.2.3, Alg. 2).
//!
//! Tensor axes are **symbolic atoms** (the paper's `i, j, k`). A reshape
//! that merges axes produces a factor list (`i⊗j`), a split refines an
//! atom into sub-atoms, and a transpose permutes axes. Two
//! reshape–transpose paths are compared by reducing both to sequences of
//! *primitive* atoms (the finest common refinement — splits are
//! hash-consed in a shared [`AtomStore`], so identical split geometry on
//! both paths yields identical sub-atoms) and then inferring the
//! reshape–transpose–reshape **bijection** that maps the distributed
//! layout onto the baseline layout. If no such bijection exists the
//! layouts are semantically different — the BSH bug of Figure 1.

mod atom;
mod expr;
mod bijection;

pub use atom::{AtomId, AtomStore};
pub use bijection::{check_bijection as bijection_check, infer_bijection, Bijection, LayoutOp};
pub use expr::{AxisExpr, LayoutError};
