//! Algorithm 2: inferring the reshape–transpose–reshape bijection that
//! maps a distributed tensor's layout onto the baseline tensor's layout.

use super::{AtomStore, AxisExpr};

/// One concrete layout operation of an inferred bijection.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayoutOp {
    /// Reshape to dims.
    Reshape(Vec<i64>),
    /// Transpose by permutation.
    Transpose(Vec<usize>),
}

/// An inferred bijection: the operation sequence that converts the
/// distributed layout into the baseline layout (paper: the
/// `(s₁, π, s₂)` reshape–transpose–reshape triple).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bijection {
    /// Concrete op sequence (empty = layouts already identical).
    pub ops: Vec<LayoutOp>,
}

impl Bijection {
    /// True when the two layouts are already elementwise identical.
    pub fn is_identity(&self) -> bool {
        self.ops.is_empty()
    }

    /// Render like the paper: `[reshape(64,4,4096), transpose(1,0,2), reshape(256,4096)]`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .ops
            .iter()
            .map(|op| match op {
                LayoutOp::Reshape(dims) => format!(
                    "reshape({})",
                    dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
                ),
                LayoutOp::Transpose(perm) => format!(
                    "transpose({})",
                    perm.iter().map(|p| p.to_string()).collect::<Vec<_>>().join(",")
                ),
            })
            .collect();
        format!("[{}]", parts.join(", "))
    }
}

/// Infer the bijection mapping `dist` onto `base` (Algorithm 2).
///
/// Both expressions must be built over the same [`AtomStore`] with shared
/// atoms (the axis map `M` of the paper is realized by constructing the
/// distributed expression from the baseline expression's atoms).
///
/// Returns `None` (the paper's ⊥) when the two layouts do not contain the
/// same primitive axes exactly once each — i.e. no reshape–transpose
/// sequence can relate them.
pub fn infer_bijection(
    store: &AtomStore,
    base: &AxisExpr,
    dist: &AxisExpr,
) -> Option<Bijection> {
    // Step 1-2: symbolic expressions are given; normalize to primitive
    // leaves (rank normalization: the finest common refinement).
    let flat_b = base.flat_leaves(store);
    let flat_d = dist.flat_leaves(store);

    // Bijection exists iff the primitive axes match as sets, each used once.
    if flat_b.len() != flat_d.len() {
        return None;
    }
    {
        let mut sb = flat_b.clone();
        let mut sd = flat_d.clone();
        sb.sort_unstable();
        sd.sort_unstable();
        if sb != sd {
            return None;
        }
        sb.dedup();
        if sb.len() != flat_b.len() {
            return None; // repeated atom: not a bijection
        }
    }

    // Fast path: structurally identical already.
    if base.structurally_equal(dist, store) {
        return Some(Bijection { ops: vec![] });
    }

    // Step 3: permutation p with p[i] = position in flat_d of flat_b[i].
    let perm: Vec<usize> = flat_b
        .iter()
        .map(|a| flat_d.iter().position(|b| b == a).expect("checked above"))
        .collect();

    // Step 4: construct the op sequence d -> b.
    let mut ops = Vec::new();
    let split_dims_d: Vec<i64> = flat_d.iter().map(|&a| store.size(a)).collect();
    let dist_dims = dist.dims(store);
    if dist_dims != split_dims_d {
        ops.push(LayoutOp::Reshape(split_dims_d));
    }
    if !perm.iter().enumerate().all(|(i, &p)| i == p) {
        ops.push(LayoutOp::Transpose(perm));
    }
    let base_dims = base.dims(store);
    let after_transpose: Vec<i64> = flat_b.iter().map(|&a| store.size(a)).collect();
    if after_transpose != base_dims {
        ops.push(LayoutOp::Reshape(base_dims));
    }

    let bij = Bijection { ops };
    debug_assert!(check_bijection(store, base, dist, &bij), "inferred bijection must validate");
    Some(bij)
}

/// Validate a bijection: applying `ops` to `dist` must produce an
/// expression structurally equal to `base` (the final check of Alg. 2).
pub fn check_bijection(
    store: &AtomStore,
    base: &AxisExpr,
    dist: &AxisExpr,
    bij: &Bijection,
) -> bool {
    let mut store = store.clone(); // splits during replay stay local
    let mut cur = dist.clone();
    for op in &bij.ops {
        cur = match op {
            LayoutOp::Reshape(dims) => match cur.reshape(&mut store, dims) {
                Ok(e) => e,
                Err(_) => return false,
            },
            LayoutOp::Transpose(perm) => match cur.transpose(perm) {
                Ok(e) => e,
                Err(_) => return false,
            },
        };
    }
    cur.structurally_equal(base, &store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::AxisExpr;

    /// The paper's Figure 9 example: baseline (4,64,4096) reshaped to
    /// (256,4096); distributed path transposes to (64,4,4096) first.
    #[test]
    fn figure9_example() {
        let mut st = AtomStore::new();
        let x = AxisExpr::from_shape(&mut st, &[4, 64, 4096]); // (i, j, k)
        // baseline path: reshape (4*64, 4096)
        let e_b = x.reshape(&mut st, &[256, 4096]).unwrap(); // (i⊗j, k)
        // distributed path: transpose (j, i, k)
        let e_d = x.transpose(&[1, 0, 2]).unwrap();

        let bij = infer_bijection(&st, &e_b, &e_d).unwrap();
        assert_eq!(
            bij.ops,
            vec![
                LayoutOp::Transpose(vec![1, 0, 2]),
                LayoutOp::Reshape(vec![256, 4096]),
            ]
        );
        assert!(check_bijection(&st, &e_b, &e_d, &bij));
        assert_eq!(bij.describe(), "[transpose(1,0,2), reshape(256,4096)]");
    }

    #[test]
    fn identity_when_paths_agree() {
        let mut st = AtomStore::new();
        let x = AxisExpr::from_shape(&mut st, &[8, 16]);
        let a = x.reshape(&mut st, &[128]).unwrap();
        let b = x.reshape(&mut st, &[128]).unwrap();
        let bij = infer_bijection(&st, &a, &b).unwrap();
        assert!(bij.is_identity());
    }

    /// The BSH bug (paper Figure 1): reshaping (s*b, h) directly to
    /// (b, s, h) is NOT the same as reshape to (s, b, h) + transpose.
    #[test]
    fn bsh_bug_detected_as_non_identity() {
        let mut st = AtomStore::new();
        // result tensor (s*b, h) where s and b are distinct atoms
        let s_atom = st.fresh(64); // sequence
        let b_atom = st.fresh(4); // batch
        let h_atom = st.fresh(4096);
        let result = AxisExpr::from_axes(vec![vec![s_atom, b_atom], vec![h_atom]]);

        // correct: reshape (s, b, h) then transpose(1,0,2) -> (b, s, h)
        let correct = result
            .reshape(&mut st, &[64, 4, 4096])
            .unwrap()
            .transpose(&[1, 0, 2])
            .unwrap();
        // buggy: reshape directly to (b, s, h) = (4, 64, 4096)
        let buggy = result.reshape(&mut st, &[4, 64, 4096]).unwrap();

        // the buggy layout is NOT structurally equal to the correct one
        assert!(!correct.structurally_equal(&buggy, &st));
        // and the bijection between them is a genuine transpose, not identity
        let bij = infer_bijection(&st, &correct, &buggy).unwrap();
        assert!(!bij.is_identity());
    }

    #[test]
    fn no_bijection_across_different_atoms() {
        let mut st = AtomStore::new();
        let a = AxisExpr::from_shape(&mut st, &[4, 8]);
        let b = AxisExpr::from_shape(&mut st, &[4, 8]); // different atoms!
        assert!(infer_bijection(&st, &a, &b).is_none());
    }

    #[test]
    fn no_bijection_when_atom_repeated() {
        let mut st = AtomStore::new();
        let i = st.fresh(4);
        let j = st.fresh(8);
        let a = AxisExpr::from_axes(vec![vec![i], vec![j]]);
        let dup = AxisExpr::from_axes(vec![vec![i], vec![i]]);
        assert!(infer_bijection(&st, &a, &dup).is_none());
    }

    #[test]
    fn split_refinement_bijection() {
        // baseline merges differently than distributed splits: (2,6) vs (4,3)
        let mut st = AtomStore::new();
        let x = AxisExpr::from_shape(&mut st, &[12]);
        let a = x.reshape(&mut st, &[2, 6]).unwrap();
        let b = x.reshape(&mut st, &[4, 3]).unwrap();
        let bij = infer_bijection(&st, &a, &b).unwrap();
        // same element order — refinement alone aligns them (reshape only)
        assert!(bij.ops.iter().all(|op| matches!(op, LayoutOp::Reshape(_))));
        assert!(check_bijection(&st, &a, &b, &bij));
    }

    #[test]
    fn three_way_permutation() {
        let mut st = AtomStore::new();
        let x = AxisExpr::from_shape(&mut st, &[2, 3, 4]);
        let b = x.transpose(&[2, 1, 0]).unwrap(); // (k, j, i)
        let d = x.transpose(&[1, 2, 0]).unwrap(); // (j, k, i)
        let bij = infer_bijection(&st, &b, &d).unwrap();
        assert_eq!(bij.ops, vec![LayoutOp::Transpose(vec![1, 0, 2])]);
        assert!(check_bijection(&st, &b, &d, &bij));
    }

    #[test]
    fn merge_of_transposed_axes_needs_full_sequence() {
        let mut st = AtomStore::new();
        let x = AxisExpr::from_shape(&mut st, &[4, 64, 4096]);
        // baseline: transpose (j,i,k) then reshape (j*i, k)
        let b = x.transpose(&[1, 0, 2]).unwrap().reshape(&mut st, &[256, 4096]).unwrap();
        // distributed: reshape (i*j, k) directly
        let d = x.reshape(&mut st, &[256, 4096]).unwrap();
        let bij = infer_bijection(&st, &b, &d).unwrap();
        assert_eq!(
            bij.ops,
            vec![
                LayoutOp::Reshape(vec![4, 64, 4096]),
                LayoutOp::Transpose(vec![1, 0, 2]),
                LayoutOp::Reshape(vec![256, 4096]),
            ]
        );
        assert!(check_bijection(&st, &b, &d, &bij));
    }
}
