//! Symbolic axis expressions: each tensor axis as an ordered factor list.

use super::{AtomId, AtomStore};
use std::collections::VecDeque;

/// Layout-analysis failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayoutError {
    /// A reshape crossed factor boundaries in a non-divisible way — outside
    /// the paper's grouping-reshape scope assumption.
    NotGrouping(String),
    /// Transpose permutation doesn't match the expression rank.
    RankMismatch {
        /// permutation length
        perm: usize,
        /// expression rank
        rank: usize,
    },
}

impl std::fmt::Display for LayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LayoutError::NotGrouping(s) => {
                write!(f, "reshape is not a grouping (merge/split) reshape: {s}")
            }
            LayoutError::RankMismatch { perm, rank } => {
                write!(f, "permutation rank {perm} != expression rank {rank}")
            }
        }
    }
}

impl std::error::Error for LayoutError {}

/// Symbolic shape: `axes[i]` is the ordered factor list of axis `i`.
///
/// `GenExp` of Algorithm 2: a shape `(4, 64, 4096)` becomes atoms
/// `(i, j, k)`; `reshape(256, 4096)` turns it into `(i⊗j, k)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AxisExpr {
    /// Factors per axis.
    pub axes: Vec<Vec<AtomId>>,
}

impl AxisExpr {
    /// Fresh expression for a concrete shape: one new atom per axis.
    pub fn from_shape(store: &mut AtomStore, dims: &[i64]) -> AxisExpr {
        AxisExpr { axes: dims.iter().map(|&d| vec![store.fresh(d)]).collect() }
    }

    /// Expression from explicit per-axis factor lists.
    pub fn from_axes(axes: Vec<Vec<AtomId>>) -> AxisExpr {
        AxisExpr { axes }
    }

    /// Rank.
    pub fn rank(&self) -> usize {
        self.axes.len()
    }

    /// Concrete dims under `store`.
    pub fn dims(&self, store: &AtomStore) -> Vec<i64> {
        self.axes.iter().map(|a| store.product(a)).collect()
    }

    /// Total element count.
    pub fn elements(&self, store: &AtomStore) -> i64 {
        self.dims(store).iter().product()
    }

    /// Apply a grouping reshape to `new_dims` (the paper's scope: merges
    /// and splits of contiguous axes).
    pub fn reshape(&self, store: &mut AtomStore, new_dims: &[i64]) -> Result<AxisExpr, LayoutError> {
        let total: i64 = new_dims.iter().product();
        if total != self.elements(store) {
            return Err(LayoutError::NotGrouping(format!(
                "element count {} -> {}",
                self.elements(store),
                total
            )));
        }
        // flatten factors row-major, then regroup
        let mut queue: VecDeque<AtomId> =
            self.axes.iter().flat_map(|a| a.iter().copied()).collect();
        let mut axes = Vec::with_capacity(new_dims.len());
        for &d in new_dims {
            if d == 1 {
                // size-1 axes carry no atoms
                axes.push(vec![]);
                continue;
            }
            let taken = store.take_product(&mut queue, d).ok_or_else(|| {
                LayoutError::NotGrouping(format!("target dim {d} misaligned with factors"))
            })?;
            axes.push(taken);
        }
        // drained exactly (all leftover atoms must be size-1)
        while let Some(a) = queue.pop_front() {
            if store.size(a) != 1 {
                return Err(LayoutError::NotGrouping("leftover factors".into()));
            }
        }
        Ok(AxisExpr { axes })
    }

    /// Apply a transpose (HLO convention: output axis `i` = input `perm[i]`).
    pub fn transpose(&self, perm: &[usize]) -> Result<AxisExpr, LayoutError> {
        if perm.len() != self.rank() {
            return Err(LayoutError::RankMismatch { perm: perm.len(), rank: self.rank() });
        }
        Ok(AxisExpr { axes: perm.iter().map(|&p| self.axes[p].clone()).collect() })
    }

    /// Fully expand every factor to primitive leaves.
    pub fn expanded(&self, store: &AtomStore) -> AxisExpr {
        AxisExpr {
            axes: self
                .axes
                .iter()
                .map(|a| a.iter().flat_map(|&f| store.expand(f)).collect())
                .collect(),
        }
    }

    /// Flat leaf sequence (row-major), size-1 leaves dropped.
    pub fn flat_leaves(&self, store: &AtomStore) -> Vec<AtomId> {
        self.expanded(store)
            .axes
            .into_iter()
            .flatten()
            .filter(|&a| store.size(a) != 1)
            .collect()
    }

    /// Structural equality under `store` (same leaves, same axis grouping).
    pub fn structurally_equal(&self, other: &AxisExpr, store: &AtomStore) -> bool {
        if self.rank() != other.rank() {
            return false;
        }
        self.expanded(store)
            .axes
            .iter()
            .zip(&other.expanded(store).axes)
            .all(|(a, b)| {
                let fa: Vec<AtomId> =
                    a.iter().copied().filter(|&x| store.size(x) != 1).collect();
                let fb: Vec<AtomId> =
                    b.iter().copied().filter(|&x| store.size(x) != 1).collect();
                fa == fb
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_merge_then_split_roundtrip() {
        let mut st = AtomStore::new();
        let e = AxisExpr::from_shape(&mut st, &[4, 64, 4096]);
        let merged = e.reshape(&mut st, &[256, 4096]).unwrap();
        assert_eq!(merged.dims(&st), vec![256, 4096]);
        assert_eq!(merged.axes[0].len(), 2); // i⊗j
        let back = merged.reshape(&mut st, &[4, 64, 4096]).unwrap();
        assert!(back.structurally_equal(&e, &st));
    }

    #[test]
    fn reshape_split_creates_subatoms() {
        let mut st = AtomStore::new();
        let e = AxisExpr::from_shape(&mut st, &[12]);
        let s = e.reshape(&mut st, &[4, 3]).unwrap();
        assert_eq!(s.dims(&st), vec![4, 3]);
        // splitting again along compatible lines reuses sub-atoms
        let s2 = e.reshape(&mut st, &[4, 3]).unwrap();
        assert!(s.structurally_equal(&s2, &st));
    }

    #[test]
    fn incompatible_split_is_refined() {
        let mut st = AtomStore::new();
        let e = AxisExpr::from_shape(&mut st, &[12]);
        let a = e.reshape(&mut st, &[4, 3]).unwrap();
        let b = e.reshape(&mut st, &[2, 6]).unwrap();
        // flat leaves of both refine to [2,2,3]
        let fa = a.flat_leaves(&st);
        let fb = b.flat_leaves(&st);
        assert_eq!(fa, fb);
        assert_eq!(fa.iter().map(|&x| st.size(x)).collect::<Vec<_>>(), vec![2, 2, 3]);
    }

    #[test]
    fn non_divisible_reshape_rejected() {
        let mut st = AtomStore::new();
        let e = AxisExpr::from_shape(&mut st, &[4, 5]);
        // 10 = 4 * 2.5 → crosses the atom boundary non-divisibly
        assert!(matches!(
            e.reshape(&mut st, &[10, 2]),
            Err(LayoutError::NotGrouping(_))
        ));
    }

    #[test]
    fn transpose_permutes_axes() {
        let mut st = AtomStore::new();
        let e = AxisExpr::from_shape(&mut st, &[2, 3, 4]);
        let t = e.transpose(&[2, 0, 1]).unwrap();
        assert_eq!(t.dims(&st), vec![4, 2, 3]);
        assert_eq!(t.axes[1], e.axes[0]);
        assert!(t.transpose(&[1, 2, 0]).unwrap().structurally_equal(&e, &st));
    }

    #[test]
    fn size_one_axes_ignored() {
        let mut st = AtomStore::new();
        let e = AxisExpr::from_shape(&mut st, &[4, 1, 8]);
        let squeezed = e.reshape(&mut st, &[4, 8]).unwrap();
        let unsqueezed = squeezed.reshape(&mut st, &[1, 4, 8, 1]).unwrap();
        assert_eq!(unsqueezed.dims(&st), vec![1, 4, 8, 1]);
        assert_eq!(
            squeezed.flat_leaves(&st),
            unsqueezed.flat_leaves(&st)
        );
    }
}
