//! Primitive symbolic axes with hash-consed refinement.

use rustc_hash::FxHashMap;

/// Symbolic axis atom (the paper's `i, j, k, i₁, i₂ …`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AtomId(pub u32);

/// Store of atoms: sizes plus (optional) refinements into sub-atoms.
///
/// Refinements are **hash-consed by geometry**: splitting atom `i` (size
/// 12) into `[4, 3]` always yields the same two sub-atoms, whichever path
/// requests the split. That is what makes structural comparison of two
/// independently rewritten layout expressions sound: equal geometry ⇒
/// equal atoms.
#[derive(Debug, Default, Clone)]
pub struct AtomStore {
    sizes: Vec<i64>,
    /// finest known refinement (direct children, in row-major order)
    children: Vec<Option<Vec<AtomId>>>,
    /// hash-cons of splits: (parent, prefix-product, size) -> child
    split_memo: FxHashMap<(AtomId, i64, i64), AtomId>,
    /// mesh axis a *shard* atom spans (absent ⇒ axis 0, the flat-mesh
    /// default). Only atoms that are distributed across cores carry a
    /// meaningful tag; geometry hash-consing is unaffected.
    mesh_axis: FxHashMap<AtomId, u8>,
}

impl AtomStore {
    /// Empty store.
    pub fn new() -> AtomStore {
        AtomStore::default()
    }

    /// Fresh primitive atom of `size`.
    pub fn fresh(&mut self, size: i64) -> AtomId {
        assert!(size >= 1, "atom size must be >= 1, got {size}");
        let id = AtomId(self.sizes.len() as u32);
        self.sizes.push(size);
        self.children.push(None);
        id
    }

    /// Size of an atom.
    pub fn size(&self, a: AtomId) -> i64 {
        self.sizes[a.0 as usize]
    }

    /// Tag `a` as spanning mesh axis `axis`. First write wins: atoms are
    /// hash-consed by geometry, so a shared split child could be reached
    /// from contexts claiming different axes — retagging would corrupt
    /// facts already derived under the first tag. Returns `false` when `a`
    /// already carries a *different* tag (callers must then skip the
    /// derivation instead of proceeding with a wrong axis).
    pub fn set_mesh_axis(&mut self, a: AtomId, axis: u8) -> bool {
        match self.mesh_axis.get(&a) {
            Some(&t) => t == axis,
            None => {
                self.mesh_axis.insert(a, axis);
                true
            }
        }
    }

    /// Mesh axis a shard atom spans (0 for untagged atoms — the flat-mesh
    /// default, which keeps every 1-axis scenario unchanged).
    pub fn mesh_axis(&self, a: AtomId) -> u8 {
        self.mesh_axis.get(&a).copied().unwrap_or(0)
    }

    /// Current finest expansion of an atom (leaves of its split tree).
    pub fn expand(&self, a: AtomId) -> Vec<AtomId> {
        match &self.children[a.0 as usize] {
            None => vec![a],
            Some(kids) => kids.iter().flat_map(|&k| self.expand(k)).collect(),
        }
    }

    /// Total size of a leaf sequence.
    pub fn product(&self, atoms: &[AtomId]) -> i64 {
        atoms.iter().map(|&a| self.size(a)).product()
    }

    fn get_or_make_child(&mut self, parent: AtomId, prefix: i64, size: i64) -> AtomId {
        if let Some(&c) = self.split_memo.get(&(parent, prefix, size)) {
            return c;
        }
        let c = self.fresh(size);
        self.split_memo.insert((parent, prefix, size), c);
        c
    }

    /// Split a **leaf** atom into row-major `factors` (product must equal
    /// its size). Hash-consed: same geometry returns the same children.
    /// Returns `None` if the atom is not a leaf or factors don't multiply
    /// to its size.
    pub fn split_leaf(&mut self, a: AtomId, factors: &[i64]) -> Option<Vec<AtomId>> {
        if self.children[a.0 as usize].is_some() {
            return None;
        }
        if factors.iter().product::<i64>() != self.size(a) {
            return None;
        }
        if factors.len() == 1 {
            return Some(vec![a]);
        }
        let mut kids = Vec::with_capacity(factors.len());
        let mut prefix = 1i64;
        for &f in factors {
            kids.push(self.get_or_make_child(a, prefix, f));
            prefix *= f;
        }
        self.children[a.0 as usize] = Some(kids.clone());
        Some(kids)
    }

    /// Take `want` elements (by product) from the front of a leaf queue,
    /// splitting the boundary leaf when needed. Returns the consumed
    /// leaves or `None` when `want` does not align with any split (the
    /// "not a grouping reshape" case → Algorithm 2's ⊥).
    pub fn take_product(
        &mut self,
        queue: &mut std::collections::VecDeque<AtomId>,
        want: i64,
    ) -> Option<Vec<AtomId>> {
        let mut got = 1i64;
        let mut out = Vec::new();
        while got < want {
            let head = queue.pop_front()?;
            // fully expand the head first so we always work on leaves
            let leaves = self.expand(head);
            if leaves.len() > 1 {
                for l in leaves.into_iter().rev() {
                    queue.push_front(l);
                }
                continue;
            }
            let sz = self.size(head);
            if got * sz <= want {
                if want % (got * sz) != 0 && got * sz != want {
                    // misaligned: would need a non-divisor split later —
                    // keep going only if it still divides the target
                }
                got *= sz;
                out.push(head);
            } else {
                // need to split `head` into [want/got, rest]
                let need = want / got;
                if need <= 1 || sz % need != 0 {
                    return None;
                }
                let kids = self.split_leaf(head, &[need, sz / need])?;
                got *= need;
                out.push(kids[0]);
                queue.push_front(kids[1]);
            }
        }
        if got == want {
            Some(out)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;

    #[test]
    fn split_is_hash_consed() {
        let mut st = AtomStore::new();
        let a = st.fresh(12);
        let k1 = st.split_leaf(a, &[4, 3]).unwrap();
        // once split, same split again isn't a leaf op — but the memo
        // makes independent geometry requests agree:
        let c = st.split_memo[&(a, 1, 4)];
        assert_eq!(k1[0], c);
        assert_eq!(st.size(k1[0]), 4);
        assert_eq!(st.size(k1[1]), 3);
        assert_eq!(st.product(&st.expand(a)), 12);
    }

    #[test]
    fn expand_recursive() {
        let mut st = AtomStore::new();
        let a = st.fresh(12);
        let kids = st.split_leaf(a, &[4, 3]).unwrap();
        let _gk = st.split_leaf(kids[0], &[2, 2]).unwrap();
        let leaves = st.expand(a);
        assert_eq!(leaves.len(), 3);
        assert_eq!(
            leaves.iter().map(|&l| st.size(l)).collect::<Vec<_>>(),
            vec![2, 2, 3]
        );
    }

    #[test]
    fn take_product_aligned() {
        let mut st = AtomStore::new();
        let a = st.fresh(4);
        let b = st.fresh(6);
        let mut q: VecDeque<AtomId> = [a, b].into_iter().collect();
        let first = st.take_product(&mut q, 8).unwrap(); // 4 * (2 of 6)
        assert_eq!(st.product(&first), 8);
        let second = st.take_product(&mut q, 3).unwrap();
        assert_eq!(st.product(&second), 3);
        assert!(q.is_empty());
    }

    #[test]
    fn take_product_misaligned_fails() {
        let mut st = AtomStore::new();
        let a = st.fresh(4);
        let b = st.fresh(5);
        let mut q: VecDeque<AtomId> = [a, b].into_iter().collect();
        // 10 needs to split the 4 into 2*2 then cross into 5 — 10/4 not integral,
        // so after taking 4 we need 10/4 → not divisible: fails... but walk:
        // got=4 then need 10/4 non-integral on the 5 → None
        assert!(st.take_product(&mut q, 10).is_none());
    }

    #[test]
    fn identical_geometry_two_paths_share_atoms() {
        let mut st = AtomStore::new();
        let a = st.fresh(64);
        // path 1 splits [4, 16]; record, then expand
        let k1 = st.split_leaf(a, &[4, 16]).unwrap();
        // path 2 wants the same prefix split via take_product
        let mut q: VecDeque<AtomId> = [a].into_iter().collect();
        let taken = st.take_product(&mut q, 4).unwrap();
        assert_eq!(taken, vec![k1[0]]);
    }
}
