//! Crate-wide typed errors.
//!
//! Every fallible operation on the user path — HLO parsing, configuration,
//! model-zoo generation, execution — returns [`ScalifyError`] instead of
//! panicking, so a long-lived [`crate::verifier::Session`] embedded in a
//! training pipeline can report malformed input and keep serving.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, ScalifyError>;

/// What went wrong, by domain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ScalifyError {
    /// Malformed HLO text / manifest input.
    Parse(String),
    /// Invalid verifier or CLI configuration.
    Config(String),
    /// Invalid or inconsistent model specification (graph structure,
    /// annotations, zoo parameters).
    ModelSpec(String),
    /// Execution failure in the runtime / interpreter.
    Runtime(String),
    /// Underlying I/O failure.
    Io(String),
}

impl ScalifyError {
    /// Parse-domain error.
    pub fn parse(msg: impl Into<String>) -> ScalifyError {
        ScalifyError::Parse(msg.into())
    }

    /// Configuration error.
    pub fn config(msg: impl Into<String>) -> ScalifyError {
        ScalifyError::Config(msg.into())
    }

    /// Model-specification error.
    pub fn model_spec(msg: impl Into<String>) -> ScalifyError {
        ScalifyError::ModelSpec(msg.into())
    }

    /// Runtime error.
    pub fn runtime(msg: impl Into<String>) -> ScalifyError {
        ScalifyError::Runtime(msg.into())
    }

    /// Error-domain label (stable, used in JSON output and exit codes).
    pub fn kind(&self) -> &'static str {
        match self {
            ScalifyError::Parse(_) => "parse",
            ScalifyError::Config(_) => "config",
            ScalifyError::ModelSpec(_) => "model-spec",
            ScalifyError::Runtime(_) => "runtime",
            ScalifyError::Io(_) => "io",
        }
    }

    /// The bare message, without the domain prefix.
    pub fn message(&self) -> &str {
        match self {
            ScalifyError::Parse(m)
            | ScalifyError::Config(m)
            | ScalifyError::ModelSpec(m)
            | ScalifyError::Runtime(m)
            | ScalifyError::Io(m) => m,
        }
    }

    /// Prefix the message with `context` (keeps the variant).
    pub fn context(self, context: impl AsRef<str>) -> ScalifyError {
        let wrap = |m: String| format!("{}: {}", context.as_ref(), m);
        match self {
            ScalifyError::Parse(m) => ScalifyError::Parse(wrap(m)),
            ScalifyError::Config(m) => ScalifyError::Config(wrap(m)),
            ScalifyError::ModelSpec(m) => ScalifyError::ModelSpec(wrap(m)),
            ScalifyError::Runtime(m) => ScalifyError::Runtime(wrap(m)),
            ScalifyError::Io(m) => ScalifyError::Io(wrap(m)),
        }
    }
}

impl fmt::Display for ScalifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} error: {}", self.kind(), self.message())
    }
}

impl std::error::Error for ScalifyError {}

impl From<std::io::Error> for ScalifyError {
    fn from(e: std::io::Error) -> ScalifyError {
        ScalifyError::Io(e.to_string())
    }
}

impl From<std::num::ParseIntError> for ScalifyError {
    fn from(e: std::num::ParseIntError) -> ScalifyError {
        ScalifyError::Parse(format!("invalid integer: {e}"))
    }
}

impl From<std::num::ParseFloatError> for ScalifyError {
    fn from(e: std::num::ParseFloatError) -> ScalifyError {
        ScalifyError::Parse(format!("invalid number: {e}"))
    }
}

impl From<crate::interp::EvalError> for ScalifyError {
    fn from(e: crate::interp::EvalError) -> ScalifyError {
        ScalifyError::Runtime(e.to_string())
    }
}

/// `anyhow::Context`-style helpers for any error convertible into
/// [`ScalifyError`].
pub trait ResultExt<T> {
    /// Add fixed context to the error.
    fn ctx(self, context: &str) -> Result<T>;
    /// Add lazily computed context to the error.
    fn with_ctx<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: Into<ScalifyError>> ResultExt<T> for std::result::Result<T, E> {
    fn ctx(self, context: &str) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_ctx<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_kind_and_message() {
        let e = ScalifyError::config("threads must be >= 1");
        assert_eq!(e.to_string(), "config error: threads must be >= 1");
        assert_eq!(e.kind(), "config");
        assert_eq!(e.message(), "threads must be >= 1");
    }

    #[test]
    fn context_prefixes_and_keeps_variant() {
        let e = ScalifyError::parse("no ENTRY computation").context("reading a.hlo");
        assert!(matches!(e, ScalifyError::Parse(_)));
        assert_eq!(e.message(), "reading a.hlo: no ENTRY computation");
    }

    #[test]
    fn from_io_error() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing.hlo");
        let e: ScalifyError = io.into();
        assert!(matches!(e, ScalifyError::Io(_)));
        assert!(e.to_string().contains("missing.hlo"));
    }

    #[test]
    fn from_eval_error() {
        let e: ScalifyError = crate::interp::EvalError::Unsupported("custom-call".into()).into();
        assert!(matches!(e, ScalifyError::Runtime(_)));
        assert!(e.message().contains("custom-call"));
    }

    #[test]
    fn result_ext_converts_and_wraps() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"));
        let e = r.with_ctx(|| "loading manifest".to_string()).unwrap_err();
        assert!(matches!(e, ScalifyError::Io(_)));
        assert!(e.message().starts_with("loading manifest: "));
    }
}
