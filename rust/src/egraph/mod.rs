//! E-graph engine: equality saturation over tensor IR terms.
//!
//! A from-scratch implementation of the egg/egglog data structure
//! (union-find + hash-consed e-nodes + congruence closure) specialized to
//! [`crate::ir::Op`] as the term language. Scalify registers the baseline
//! and distributed subgraphs of each layer into **one** e-graph, runs the
//! rewrite rules to saturation, and lets the relational analysis
//! ([`crate::relations`]) work over canonical e-class ids — two nodes
//! whose classes merge are semantically equal, and every union is
//! justified by a rewrite rule (soundness, paper §5.1).

mod engine;
mod rewrite;
pub mod runner;

pub use engine::{EClass, EGraph, ENode, Id, Origin};
pub use rewrite::{default_rules, Rewrite, RuleSet};
pub use runner::{RunLimits, RunReport, Runner, StopReason};
