//! E-graph engine: equality saturation over tensor IR terms.
//!
//! A from-scratch implementation of the egg/egglog data structure
//! (union-find + hash-consed e-nodes + congruence closure) specialized to
//! [`crate::ir::Op`] as the term language. Scalify registers the baseline
//! and distributed subgraphs of each layer into **one** e-graph, runs the
//! rewrite rules to saturation, and lets the relational analysis
//! ([`crate::relations`]) work over canonical e-class ids — two nodes
//! whose classes merge are semantically equal, and every union is
//! justified by a rewrite rule (soundness, paper §5.1).
//!
//! The hot path is engineered for scale (the paper's "405B in minutes"
//! claim): operators are interned ([`OpId`]) so hash-consing never clones
//! attribute payloads, rules e-match through a classes-by-root-op index
//! with per-rule dirty cursors ([`MatchCursor`]), congruence restoration
//! happens once per iteration, and a backoff scheduler throttles
//! match-heavy rules ([`RunLimits::match_limit`]).

mod engine;
mod rewrite;
pub mod runner;

pub use engine::{
    kind_bit, kind_bits, op_kind, CNode, EClass, EGraph, ENode, Id, MatchCursor, OpId, OpKind,
    Origin, ShapeConflict, N_KINDS,
};
pub use rewrite::{default_rules, Rewrite, RuleSet};
pub use runner::{
    merge_rule_stats, MatchMode, RuleStat, RunLimits, RunReport, Runner, StopReason,
};
