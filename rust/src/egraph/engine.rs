//! Core e-graph: union-find, interned hash-consing, congruence closure.
//!
//! Operators are **interned**: each distinct [`Op`] (attributes included)
//! is stored once in an op table and e-nodes carry a 4-byte [`OpId`] plus
//! an inline small-vector of child class ids ([`CNode`]). Canonicalizing
//! an e-node for a hash-cons lookup therefore copies a handful of `u32`s
//! — never an `Op` payload with heap `String`s, which used to dominate
//! the saturation profile.
//!
//! The graph also maintains the **match index** the incremental e-matcher
//! consumes: per-[`OpKind`] append-only logs of classes that were created
//! or changed (merged, re-canonicalized, analysis updated). A rewrite
//! rule holding a [`MatchCursor`] only re-examines classes logged since
//! it last ran — see [`EGraph::candidates`].

use crate::ir::{NodeId, Op, Shape};
use rustc_hash::{FxHashMap, FxHashSet};

/// E-class id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Id(pub u32);

impl Id {
    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Interned operator handle (index into the e-graph's op table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub u32);

/// Root-operator buckets of the match index. Every [`Op`] variant maps to
/// exactly one kind; rules declare the kinds their pattern can match at
/// the root so the matcher never feeds them classes of other shapes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum OpKind {
    /// `parameter`
    Parameter = 0,
    /// `constant`
    Constant,
    /// `iota`
    Iota,
    /// `add`
    Add,
    /// `subtract`
    Sub,
    /// `multiply`
    Mul,
    /// `divide`
    Div,
    /// `maximum`
    Max,
    /// `minimum`
    Min,
    /// `power`
    Pow,
    /// `negate`
    Neg,
    /// `exponential`
    Exp,
    /// `log`
    Log,
    /// `tanh`
    Tanh,
    /// `rsqrt`
    Rsqrt,
    /// `sqrt`
    Sqrt,
    /// `abs`
    Abs,
    /// `logistic`
    Logistic,
    /// `sine`
    Sin,
    /// `cosine`
    Cos,
    /// `convert`
    Convert,
    /// `dot`
    Dot,
    /// `reshape`
    Reshape,
    /// `transpose`
    Transpose,
    /// `slice`
    Slice,
    /// `concatenate`
    Concat,
    /// `broadcast`
    Broadcast,
    /// `reduce`
    Reduce,
    /// `select`
    Select,
    /// `compare`
    Compare,
    /// `all-reduce`
    AllReduce,
    /// `all-gather`
    AllGather,
    /// `reduce-scatter`
    ReduceScatter,
    /// `all-to-all`
    AllToAll,
    /// `send`
    Send,
    /// `recv`
    Recv,
    /// `tuple`
    Tuple,
    /// `get-tuple-element`
    GetTupleElement,
    /// uninterpreted custom call
    Custom,
}

/// Number of [`OpKind`] buckets (fits a `u64` bitmask).
pub const N_KINDS: usize = 39;

/// The kind bucket of an operator.
pub fn op_kind(op: &Op) -> OpKind {
    match op {
        Op::Parameter { .. } => OpKind::Parameter,
        Op::Constant(_) => OpKind::Constant,
        Op::Iota { .. } => OpKind::Iota,
        Op::Add => OpKind::Add,
        Op::Sub => OpKind::Sub,
        Op::Mul => OpKind::Mul,
        Op::Div => OpKind::Div,
        Op::Max => OpKind::Max,
        Op::Min => OpKind::Min,
        Op::Pow => OpKind::Pow,
        Op::Neg => OpKind::Neg,
        Op::Exp => OpKind::Exp,
        Op::Log => OpKind::Log,
        Op::Tanh => OpKind::Tanh,
        Op::Rsqrt => OpKind::Rsqrt,
        Op::Sqrt => OpKind::Sqrt,
        Op::Abs => OpKind::Abs,
        Op::Logistic => OpKind::Logistic,
        Op::Sin => OpKind::Sin,
        Op::Cos => OpKind::Cos,
        Op::Convert { .. } => OpKind::Convert,
        Op::Dot { .. } => OpKind::Dot,
        Op::Reshape { .. } => OpKind::Reshape,
        Op::Transpose { .. } => OpKind::Transpose,
        Op::Slice { .. } => OpKind::Slice,
        Op::Concat { .. } => OpKind::Concat,
        Op::Broadcast { .. } => OpKind::Broadcast,
        Op::Reduce { .. } => OpKind::Reduce,
        Op::Select => OpKind::Select,
        Op::Compare(_) => OpKind::Compare,
        Op::AllReduce { .. } => OpKind::AllReduce,
        Op::AllGather { .. } => OpKind::AllGather,
        Op::ReduceScatter { .. } => OpKind::ReduceScatter,
        Op::AllToAll { .. } => OpKind::AllToAll,
        Op::Send { .. } => OpKind::Send,
        Op::Recv { .. } => OpKind::Recv,
        Op::Tuple => OpKind::Tuple,
        Op::GetTupleElement { .. } => OpKind::GetTupleElement,
        Op::Custom { .. } => OpKind::Custom,
    }
}

/// Bit of one kind in a roots mask.
pub fn kind_bit(k: OpKind) -> u64 {
    1u64 << (k as u8)
}

/// Roots mask of several kinds (what [`super::Rewrite::roots`] returns).
pub fn kind_bits(kinds: &[OpKind]) -> u64 {
    kinds.iter().fold(0u64, |m, &k| m | kind_bit(k))
}

/// How many child ids a [`CNode`] stores inline before spilling.
const INLINE_CHILDREN: usize = 3;
const SPILLED: u8 = u8::MAX;

/// Child-id list with inline storage for the common arities (<= 3).
#[derive(Clone, Debug)]
pub struct Children {
    len: u8,
    inline: [Id; INLINE_CHILDREN],
    spill: Vec<Id>,
}

impl Children {
    fn from_slice(ids: &[Id]) -> Children {
        if ids.len() <= INLINE_CHILDREN {
            let mut inline = [Id(0); INLINE_CHILDREN];
            inline[..ids.len()].copy_from_slice(ids);
            Children { len: ids.len() as u8, inline, spill: Vec::new() }
        } else {
            Children { len: SPILLED, inline: [Id(0); INLINE_CHILDREN], spill: ids.to_vec() }
        }
    }

    fn as_slice(&self) -> &[Id] {
        if self.len == SPILLED {
            &self.spill
        } else {
            &self.inline[..self.len as usize]
        }
    }

    fn as_mut_slice(&mut self) -> &mut [Id] {
        if self.len == SPILLED {
            &mut self.spill
        } else {
            &mut self.inline[..self.len as usize]
        }
    }
}

impl PartialEq for Children {
    fn eq(&self, other: &Children) -> bool {
        self.as_slice() == other.as_slice()
    }
}
impl Eq for Children {}
impl std::hash::Hash for Children {
    fn hash<H: std::hash::Hasher>(&self, h: &mut H) {
        self.as_slice().hash(h)
    }
}

/// Compact interned e-node: operator handle + child classes. This is what
/// the hash-cons memo and the class node lists store; canonicalizing one
/// copies 4-byte ids, never operator payloads. Resolve the operator with
/// [`EGraph::op`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CNode {
    /// Interned operator.
    pub op: OpId,
    children: Children,
}

impl CNode {
    /// Child e-class ids.
    pub fn children(&self) -> &[Id] {
        self.children.as_slice()
    }

    fn canonical(&self, eg: &EGraph) -> CNode {
        let mut c = self.clone();
        for id in c.children.as_mut_slice() {
            *id = eg.find(*id);
        }
        c
    }
}

/// An e-node in construction form: operator + child e-classes. This is
/// the API type [`EGraph::add`]/[`EGraph::lookup`] accept; internally the
/// operator is interned and the node stored as a [`CNode`].
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ENode {
    /// Operator (attributes included — two `transpose`s with different
    /// permutations are different e-nodes).
    pub op: Op,
    /// Child e-class ids.
    pub children: Vec<Id>,
}

impl ENode {
    /// Construct.
    pub fn new(op: Op, children: Vec<Id>) -> ENode {
        ENode { op, children }
    }
}

/// Which graph(s) of the verified pair a class's terms came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Origin {
    /// Contains a term from the baseline graph.
    pub baseline: bool,
    /// Contains a term from the distributed graph.
    pub distributed: bool,
}

impl Origin {
    /// Neither graph (derived terms only).
    pub fn derived() -> Origin {
        Origin { baseline: false, distributed: false }
    }
}

/// Per-class analysis data (egg's "analysis"): shape, scalar-constant
/// value for folding, and a representative IR node for localization.
#[derive(Clone, Debug)]
pub struct ClassData {
    /// Output shape of terms in this class. All terms must agree; a
    /// disagreement on merge is recorded as a [`ShapeConflict`] the
    /// verifier surfaces as a typed discrepancy.
    pub shape: Option<Shape>,
    /// If the class is a known scalar constant.
    pub constant: Option<f64>,
    /// Origin flags.
    pub origin: Origin,
    /// Representative source node: (is_distributed, node id) — kept for
    /// bug localization so every class can be mapped back to program text.
    pub repr: Option<(bool, NodeId)>,
}

impl ClassData {
    fn empty() -> ClassData {
        ClassData { shape: None, constant: None, origin: Origin::derived(), repr: None }
    }

    fn merge(&mut self, other: &ClassData) {
        if self.shape.is_none() {
            self.shape = other.shape.clone();
        }
        if self.constant.is_none() {
            self.constant = other.constant;
        }
        self.origin.baseline |= other.origin.baseline;
        self.origin.distributed |= other.origin.distributed;
        if self.repr.is_none() {
            self.repr = other.repr;
        }
    }
}

/// A union merged two classes whose analyses disagree on shape. Rules
/// only union terms they proved equal, and equal terms have equal shapes
/// — so a conflict means the merge was *not* semantics-preserving and the
/// layer verdict must not silently keep the first shape (it becomes a
/// typed "merged classes disagree on shape" discrepancy).
#[derive(Clone, Debug)]
pub struct ShapeConflict {
    /// Surviving canonical class.
    pub class: Id,
    /// Shape kept by the merge.
    pub kept: Shape,
    /// Shape the merged-away class carried.
    pub dropped: Shape,
    /// Representative source node of either side, for localization.
    pub repr: Option<(bool, NodeId)>,
}

/// One equivalence class of terms.
#[derive(Clone, Debug)]
pub struct EClass {
    /// Canonical id (valid right after `rebuild`).
    pub id: Id,
    /// Terms in the class (compact interned form).
    pub nodes: Vec<CNode>,
    /// (parent e-node, parent class) pairs for congruence propagation.
    pub parents: Vec<(CNode, Id)>,
    /// Analysis data.
    pub data: ClassData,
    /// Bitmask of the [`OpKind`]s present among `nodes` (may be a
    /// superset after dedup; never an undercount).
    kinds: u64,
}

impl EClass {
    /// Kind bitmask of the class's nodes.
    pub fn kinds(&self) -> u64 {
        self.kinds
    }
}

/// Cursor into the per-kind match logs; one per (rule, e-graph) pairing.
/// A fresh cursor replays the whole history, which is exactly the "first
/// iteration scans everything" behavior incremental matching needs.
#[derive(Clone, Debug)]
pub struct MatchCursor {
    pos: Vec<usize>,
}

impl MatchCursor {
    /// Cursor at the beginning of every log.
    pub fn new() -> MatchCursor {
        MatchCursor { pos: vec![0; N_KINDS] }
    }
}

impl Default for MatchCursor {
    fn default() -> Self {
        MatchCursor::new()
    }
}

/// The e-graph.
pub struct EGraph {
    uf: Vec<u32>,
    ops: Vec<Op>,
    op_kinds: Vec<OpKind>,
    op_ids: FxHashMap<Op, u32>,
    memo: FxHashMap<CNode, Id>,
    classes: FxHashMap<Id, EClass>,
    worklist: Vec<Id>,
    /// Per-kind append-only logs of classes to (re)examine. Entries may
    /// be stale (merged away); consumers canonicalize via `find`.
    kind_log: Vec<Vec<Id>>,
    node_total: usize,
    shape_conflicts: Vec<ShapeConflict>,
    /// Number of `union` calls that actually merged two classes.
    pub merges: usize,
}

impl Default for EGraph {
    fn default() -> Self {
        Self::new()
    }
}

impl EGraph {
    /// Empty e-graph.
    pub fn new() -> EGraph {
        EGraph {
            uf: Vec::new(),
            ops: Vec::new(),
            op_kinds: Vec::new(),
            op_ids: FxHashMap::default(),
            memo: FxHashMap::default(),
            classes: FxHashMap::default(),
            worklist: Vec::new(),
            kind_log: vec![Vec::new(); N_KINDS],
            node_total: 0,
            shape_conflicts: Vec::new(),
            merges: 0,
        }
    }

    /// Canonical id of `id` (no path compression; usable with `&self`).
    pub fn find(&self, mut id: Id) -> Id {
        while self.uf[id.idx()] != id.0 {
            id = Id(self.uf[id.idx()]);
        }
        id
    }

    fn find_mut(&mut self, mut id: Id) -> Id {
        while self.uf[id.idx()] != id.0 {
            let grand = self.uf[self.uf[id.idx()] as usize];
            self.uf[id.idx()] = grand;
            id = Id(grand);
        }
        id
    }

    /// Resolve an interned operator handle.
    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id.0 as usize]
    }

    /// Kind bucket of an interned operator.
    pub fn op_kind_of(&self, id: OpId) -> OpKind {
        self.op_kinds[id.0 as usize]
    }

    /// Distinct operators interned so far.
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }

    fn intern_op(&mut self, op: &Op) -> OpId {
        if let Some(&i) = self.op_ids.get(op) {
            return OpId(i);
        }
        let i = self.ops.len() as u32;
        self.ops.push(op.clone());
        self.op_kinds.push(op_kind(op));
        self.op_ids.insert(op.clone(), i);
        OpId(i)
    }

    /// Number of canonical classes.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// Total e-nodes across classes (maintained incrementally; O(1)).
    pub fn node_count(&self) -> usize {
        self.node_total
    }

    /// Iterate canonical classes.
    pub fn classes(&self) -> impl Iterator<Item = &EClass> {
        self.classes.values()
    }

    /// Class by (canonical) id.
    pub fn class(&self, id: Id) -> &EClass {
        let canon = self.find(id);
        &self.classes[&canon]
    }

    fn mark_kinds(&mut self, id: Id, mask: u64) {
        let mut m = mask;
        while m != 0 {
            let k = m.trailing_zeros() as usize;
            m &= m - 1;
            self.kind_log[k].push(id);
        }
    }

    /// Collect `(class, root-kind)` re-log marks for the parents of
    /// `canon`, i.e. the classes whose nodes consume it.
    fn parent_marks(&self, canon: Id) -> Vec<(Id, OpKind)> {
        match self.classes.get(&canon) {
            Some(class) => class
                .parents
                .iter()
                .map(|(pnode, pclass)| (*pclass, self.op_kinds[pnode.op.0 as usize]))
                .collect(),
            None => Vec::new(),
        }
    }

    /// Mutable class data by id. Analysis writes can enable new matches
    /// at the class, at its parents, and — because rule patterns read at
    /// most *grandchild* analysis data (e.g. div-to-mul-recip reading the
    /// constant under a broadcast) — at its grandparents, so all three
    /// levels are re-logged for the incremental matcher. Rules with
    /// deeper patterns must not be added without extending this (the
    /// matcher-differential property guards the invariant).
    pub fn data_mut(&mut self, id: Id) -> &mut ClassData {
        let canon = self.find(id);
        let mask = self.classes[&canon].kinds;
        let parents = self.parent_marks(canon);
        let mut marks = parents.clone();
        for (p, _) in &parents {
            let p = self.find(*p);
            marks.extend(self.parent_marks(p));
        }
        self.mark_kinds(canon, mask);
        for (c, k) in marks {
            let c = self.find(c);
            self.kind_log[k as usize].push(c);
        }
        &mut self.classes.get_mut(&canon).unwrap().data
    }

    /// Add an e-node, returning its class (hash-consed).
    pub fn add(&mut self, enode: ENode) -> Id {
        let op = self.intern_op(&enode.op);
        let children: Vec<Id> = enode.children.iter().map(|&c| self.find(c)).collect();
        self.add_interned(op, &children)
    }

    fn add_interned(&mut self, op: OpId, children: &[Id]) -> Id {
        let cnode = CNode { op, children: Children::from_slice(children) };
        if let Some(&id) = self.memo.get(&cnode) {
            return self.find(id);
        }
        let id = Id(self.uf.len() as u32);
        self.uf.push(id.0);
        let mut data = ClassData::empty();
        if let Op::Constant(crate::ir::ConstVal::Scalar(v)) = &self.ops[op.0 as usize] {
            data.constant = Some(*v);
        }
        let kind = self.op_kinds[op.0 as usize];
        let class = EClass {
            id,
            nodes: vec![cnode.clone()],
            parents: Vec::new(),
            data,
            kinds: kind_bit(kind),
        };
        for &child in children {
            self.classes.get_mut(&child).unwrap().parents.push((cnode.clone(), id));
        }
        self.classes.insert(id, class);
        self.memo.insert(cnode, id);
        self.kind_log[kind as usize].push(id);
        self.node_total += 1;
        id
    }

    /// Add with analysis data attached (shape, origin, representative).
    pub fn add_with_data(
        &mut self,
        enode: ENode,
        shape: Shape,
        from_distributed: bool,
        repr: NodeId,
    ) -> Id {
        let id = self.add(enode);
        let data = self.data_mut(id);
        if data.shape.is_none() {
            data.shape = Some(shape);
        }
        if from_distributed {
            data.origin.distributed = true;
        } else {
            data.origin.baseline = true;
        }
        if data.repr.is_none() {
            data.repr = Some((from_distributed, repr));
        }
        id
    }

    /// Merge two classes. Returns the surviving canonical id.
    pub fn union(&mut self, a: Id, b: Id) -> Id {
        let a = self.find_mut(a);
        let b = self.find_mut(b);
        if a == b {
            return a;
        }
        self.merges += 1;
        // keep the class with more parents as root (union by size-ish)
        let (root, child) = if self.classes[&a].parents.len() >= self.classes[&b].parents.len()
        {
            (a, b)
        } else {
            (b, a)
        };
        self.uf[child.idx()] = root.0;
        let child_class = self.classes.remove(&child).unwrap();
        let kinds_all;
        let conflict;
        {
            let root_class = self.classes.get_mut(&root).unwrap();
            conflict = match (&root_class.data.shape, &child_class.data.shape) {
                (Some(kept), Some(dropped)) if kept != dropped => Some(ShapeConflict {
                    class: root,
                    kept: kept.clone(),
                    dropped: dropped.clone(),
                    repr: root_class.data.repr.or(child_class.data.repr),
                }),
                _ => None,
            };
            root_class.data.merge(&child_class.data);
            kinds_all = root_class.kinds | child_class.kinds;
            root_class.kinds = kinds_all;
            root_class.nodes.extend(child_class.nodes);
            root_class.parents.extend(child_class.parents);
        }
        if let Some(c) = conflict {
            self.shape_conflicts.push(c);
        }
        self.worklist.push(root);
        // the survivor gained terms and/or analysis data: every rule whose
        // root kind it now contains must re-examine it
        self.mark_kinds(root, kinds_all);
        root
    }

    /// Shape disagreements recorded by merges (empty in a sound run).
    pub fn shape_conflicts(&self) -> &[ShapeConflict] {
        &self.shape_conflicts
    }

    /// Restore congruence invariants after unions (egg's `rebuild`),
    /// deferred to once per runner iteration. Only classes actually
    /// touched by merges have their node lists re-canonicalized, and
    /// every touched parent is re-logged for the incremental matcher.
    pub fn rebuild(&mut self) {
        let mut touched: FxHashSet<Id> = FxHashSet::default();
        let mut reparented: Vec<Id> = Vec::new();
        while let Some(id) = self.worklist.pop() {
            let canon = self.find_mut(id);
            touched.insert(canon);
            let parents = std::mem::take(&mut self.classes.get_mut(&canon).unwrap().parents);
            let mut new_parents: FxHashMap<CNode, Id> = FxHashMap::default();
            for (pnode, pclass) in parents {
                let pnode_canon = pnode.canonical(self);
                self.memo.remove(&pnode);
                let pclass = self.find_mut(pclass);
                if let Some(&existing) = self.memo.get(&pnode_canon) {
                    let existing = self.find_mut(existing);
                    if existing != pclass {
                        self.union(existing, pclass);
                    }
                }
                let pclass = self.find_mut(pclass);
                self.memo.insert(pnode_canon.clone(), pclass);
                // this parent's node points at a merged child: rules
                // rooted at its operator must re-examine the parent class
                let k = self.op_kinds[pnode_canon.op.0 as usize];
                self.kind_log[k as usize].push(pclass);
                touched.insert(pclass);
                reparented.push(pclass);
                new_parents.insert(pnode_canon, pclass);
            }
            let canon = self.find_mut(canon);
            self.classes
                .get_mut(&canon)
                .unwrap()
                .parents
                .extend(new_parents.into_iter());
        }
        // canonicalize the node lists of touched classes so pattern scans
        // see canonical ids (hash-based dedup; the untouched majority of
        // classes skips this pass entirely)
        for raw in touched {
            let canon = self.find(raw);
            let Some(mut class) = self.classes.remove(&canon) else { continue };
            for n in class.nodes.iter_mut() {
                for c in n.children.as_mut_slice() {
                    *c = self.find(*c);
                }
            }
            let before = class.nodes.len();
            let mut seen: FxHashSet<CNode> = FxHashSet::default();
            class.nodes.retain(|n| seen.insert(n.clone()));
            self.node_total -= before - class.nodes.len();
            class.id = canon;
            self.classes.insert(canon, class);
        }
        // dirtiness propagates one more hop: a merge changed every
        // reparented class's view of its children, and rule patterns read
        // up to grandchild analysis data — so the reparented classes'
        // *own* parents must also be re-offered (see `data_mut`)
        let mut grand: Vec<(Id, OpKind)> = Vec::new();
        for p in reparented {
            let p = self.find(p);
            grand.extend(self.parent_marks(p));
        }
        for (c, k) in grand {
            let c = self.find(c);
            self.kind_log[k as usize].push(c);
        }
    }

    /// Memo lookup: is there already a class containing exactly this
    /// (canonicalized) e-node? Used by the relation analysis to find the
    /// baseline partner of a distributed op.
    pub fn lookup(&self, enode: &ENode) -> Option<Id> {
        let &opi = self.op_ids.get(&enode.op)?;
        let children: Vec<Id> = enode.children.iter().map(|&c| self.find(c)).collect();
        let cnode = CNode { op: OpId(opi), children: Children::from_slice(&children) };
        self.memo.get(&cnode).map(|&id| self.find(id))
    }

    /// True when `a` and `b` are in the same class.
    pub fn same(&self, a: Id, b: Id) -> bool {
        self.find(a) == self.find(b)
    }

    /// Collect `(class, node)` candidates whose operator kind is in the
    /// `roots` mask, drawn from the per-kind logs past `cursor` (which
    /// advances). `tried` counts every node examined — the e-match work
    /// metric the scale bench reports.
    pub fn candidates(
        &self,
        roots: u64,
        cursor: &mut MatchCursor,
        tried: &mut usize,
    ) -> Vec<(Id, CNode)> {
        let mut seen: FxHashSet<Id> = FxHashSet::default();
        let mut out = Vec::new();
        let mut m = roots;
        while m != 0 {
            let k = m.trailing_zeros() as usize;
            m &= m - 1;
            let log = &self.kind_log[k];
            let start = cursor.pos[k];
            cursor.pos[k] = log.len();
            for &raw in &log[start..] {
                let id = self.find(raw);
                let Some(class) = self.classes.get(&id) else { continue };
                if !seen.insert(id) {
                    continue;
                }
                *tried += class.nodes.len();
                for n in &class.nodes {
                    if roots & kind_bit(self.op_kinds[n.op.0 as usize]) != 0 {
                        out.push((id, n.clone()));
                    }
                }
            }
        }
        out
    }

    /// The naive full rescan: every class, every node, every call — the
    /// pre-index behavior, kept for differential testing and the bench
    /// comparison. Same output shape as [`EGraph::candidates`].
    pub fn candidates_naive(&self, roots: u64, tried: &mut usize) -> Vec<(Id, CNode)> {
        let mut out = Vec::new();
        for class in self.classes.values() {
            *tried += class.nodes.len();
            for n in &class.nodes {
                if roots & kind_bit(self.op_kinds[n.op.0 as usize]) != 0 {
                    out.push((class.id, n.clone()));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ConstVal, DType};

    fn leaf(eg: &mut EGraph, name: &str) -> Id {
        eg.add(ENode::new(Op::Parameter { index: 0, name: name.into() }, vec![]))
    }

    #[test]
    fn hashcons_dedups() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let a = eg.add(ENode::new(Op::Exp, vec![x]));
        let b = eg.add(ENode::new(Op::Exp, vec![x]));
        assert_eq!(a, b);
        assert_eq!(eg.class_count(), 2);
        assert_eq!(eg.node_count(), 2);
    }

    #[test]
    fn ops_are_interned_once() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        eg.add(ENode::new(Op::Exp, vec![x]));
        eg.add(ENode::new(Op::Exp, vec![y]));
        // two distinct parameters + one shared Exp operator
        assert_eq!(eg.op_count(), 3);
    }

    #[test]
    fn congruence_closure_merges_parents() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        let fx = eg.add(ENode::new(Op::Exp, vec![x]));
        let fy = eg.add(ENode::new(Op::Exp, vec![y]));
        assert!(!eg.same(fx, fy));
        eg.union(x, y);
        eg.rebuild();
        assert!(eg.same(fx, fy), "congruence: x=y implies f(x)=f(y)");
    }

    #[test]
    fn deep_congruence_chain() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        let mut cx = x;
        let mut cy = y;
        for _ in 0..10 {
            cx = eg.add(ENode::new(Op::Neg, vec![cx]));
            cy = eg.add(ENode::new(Op::Neg, vec![cy]));
        }
        eg.union(x, y);
        eg.rebuild();
        assert!(eg.same(cx, cy));
    }

    #[test]
    fn union_is_idempotent() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        eg.union(x, y);
        let m = eg.merges;
        eg.union(x, y);
        assert_eq!(eg.merges, m);
    }

    #[test]
    fn constant_data_tracked() {
        let mut eg = EGraph::new();
        let c = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(2.5)), vec![]));
        assert_eq!(eg.class(c).data.constant, Some(2.5));
    }

    #[test]
    fn origin_merges() {
        let mut eg = EGraph::new();
        let x = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "b".into() }, vec![]),
            Shape::scalar(DType::F32),
            false,
            NodeId(0),
        );
        let y = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "d".into() }, vec![]),
            Shape::scalar(DType::F32),
            true,
            NodeId(0),
        );
        eg.union(x, y);
        eg.rebuild();
        let o = eg.class(x).data.origin;
        assert!(o.baseline && o.distributed);
    }

    #[test]
    fn shape_conflicts_are_recorded() {
        let mut eg = EGraph::new();
        let x = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "x".into() }, vec![]),
            Shape::new(DType::F32, vec![2, 3]),
            false,
            NodeId(0),
        );
        let y = eg.add_with_data(
            ENode::new(Op::Parameter { index: 1, name: "y".into() }, vec![]),
            Shape::new(DType::F32, vec![4]),
            true,
            NodeId(1),
        );
        assert!(eg.shape_conflicts().is_empty());
        eg.union(x, y);
        eg.rebuild();
        let conflicts = eg.shape_conflicts();
        assert_eq!(conflicts.len(), 1);
        assert_ne!(conflicts[0].kept, conflicts[0].dropped);
        // agreeing merges record nothing
        let mut eg = EGraph::new();
        let a = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "a".into() }, vec![]),
            Shape::new(DType::F32, vec![2]),
            false,
            NodeId(0),
        );
        let b = eg.add_with_data(
            ENode::new(Op::Parameter { index: 1, name: "b".into() }, vec![]),
            Shape::new(DType::F32, vec![2]),
            true,
            NodeId(1),
        );
        eg.union(a, b);
        eg.rebuild();
        assert!(eg.shape_conflicts().is_empty());
    }

    #[test]
    fn candidates_are_incremental() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let t = eg.add(ENode::new(Op::Transpose { perm: vec![1, 0] }, vec![x]));
        let roots = kind_bits(&[OpKind::Transpose]);
        let mut cursor = MatchCursor::new();
        let mut tried = 0;
        let first = eg.candidates(roots, &mut cursor, &mut tried);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].0, t);
        assert!(tried >= 1);
        // nothing changed: the cursor has consumed the log
        let again = eg.candidates(roots, &mut cursor, &mut tried);
        assert!(again.is_empty());
        // a new transpose shows up incrementally
        let y = leaf(&mut eg, "y");
        let t2 = eg.add(ENode::new(Op::Transpose { perm: vec![1, 0] }, vec![y]));
        let fresh = eg.candidates(roots, &mut cursor, &mut tried);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].0, t2);
        // the naive matcher rescans both every time
        let mut naive_tried = 0;
        let naive = eg.candidates_naive(roots, &mut naive_tried);
        assert_eq!(naive.len(), 2);
        assert_eq!(naive_tried, eg.node_count());
    }

    #[test]
    fn merged_classes_reenter_the_match_log() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        let fx = eg.add(ENode::new(Op::Exp, vec![x]));
        let _fy = eg.add(ENode::new(Op::Exp, vec![y]));
        let roots = kind_bits(&[OpKind::Exp]);
        let mut cursor = MatchCursor::new();
        let mut tried = 0;
        let first = eg.candidates(roots, &mut cursor, &mut tried);
        assert_eq!(first.len(), 2);
        assert!(eg.candidates(roots, &mut cursor, &mut tried).is_empty());
        // merging the children re-logs the parents (congruence changed them)
        eg.union(x, y);
        eg.rebuild();
        let after = eg.candidates(roots, &mut cursor, &mut tried);
        assert!(
            after.iter().any(|(c, _)| eg.same(*c, fx)),
            "merged parent class must be re-offered to exp-root rules"
        );
    }
}
