//! Core e-graph: union-find, hash-consing, congruence closure.

use crate::ir::{NodeId, Op, Shape};
use rustc_hash::FxHashMap;

/// E-class id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Id(pub u32);

impl Id {
    fn idx(self) -> usize {
        self.0 as usize
    }
}

/// An e-node: operator + child e-classes.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ENode {
    /// Operator (attributes included — two `transpose`s with different
    /// permutations are different e-nodes).
    pub op: Op,
    /// Child e-class ids.
    pub children: Vec<Id>,
}

impl ENode {
    /// Construct.
    pub fn new(op: Op, children: Vec<Id>) -> ENode {
        ENode { op, children }
    }

    fn canonicalize(&self, eg: &EGraph) -> ENode {
        ENode {
            op: self.op.clone(),
            children: self.children.iter().map(|&c| eg.find(c)).collect(),
        }
    }
}

/// Which graph(s) of the verified pair a class's terms came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Origin {
    /// Contains a term from the baseline graph.
    pub baseline: bool,
    /// Contains a term from the distributed graph.
    pub distributed: bool,
}

impl Origin {
    /// Neither graph (derived terms only).
    pub fn derived() -> Origin {
        Origin { baseline: false, distributed: false }
    }
}

/// Per-class analysis data (egg's "analysis"): shape, scalar-constant
/// value for folding, and a representative IR node for localization.
#[derive(Clone, Debug)]
pub struct ClassData {
    /// Output shape of terms in this class (all terms agree; checked on
    /// merge in debug builds).
    pub shape: Option<Shape>,
    /// If the class is a known scalar constant.
    pub constant: Option<f64>,
    /// Origin flags.
    pub origin: Origin,
    /// Representative source node: (is_distributed, node id) — kept for
    /// bug localization so every class can be mapped back to program text.
    pub repr: Option<(bool, NodeId)>,
}

impl ClassData {
    fn empty() -> ClassData {
        ClassData { shape: None, constant: None, origin: Origin::derived(), repr: None }
    }

    fn merge(&mut self, other: &ClassData) {
        if self.shape.is_none() {
            self.shape = other.shape.clone();
        }
        if self.constant.is_none() {
            self.constant = other.constant;
        }
        self.origin.baseline |= other.origin.baseline;
        self.origin.distributed |= other.origin.distributed;
        if self.repr.is_none() {
            self.repr = other.repr;
        }
    }
}

/// One equivalence class of terms.
#[derive(Clone, Debug)]
pub struct EClass {
    /// Canonical id (valid right after `rebuild`).
    pub id: Id,
    /// Terms in the class.
    pub nodes: Vec<ENode>,
    /// (parent e-node, parent class) pairs for congruence propagation.
    pub parents: Vec<(ENode, Id)>,
    /// Analysis data.
    pub data: ClassData,
}

/// The e-graph.
pub struct EGraph {
    uf: Vec<u32>,
    memo: FxHashMap<ENode, Id>,
    classes: FxHashMap<Id, EClass>,
    worklist: Vec<Id>,
    /// Number of `union` calls that actually merged two classes.
    pub merges: usize,
}

impl Default for EGraph {
    fn default() -> Self {
        Self::new()
    }
}

impl EGraph {
    /// Empty e-graph.
    pub fn new() -> EGraph {
        EGraph {
            uf: Vec::new(),
            memo: FxHashMap::default(),
            classes: FxHashMap::default(),
            worklist: Vec::new(),
            merges: 0,
        }
    }

    /// Canonical id of `id` (path-halving find).
    pub fn find(&self, mut id: Id) -> Id {
        while self.uf[id.idx()] != id.0 {
            id = Id(self.uf[id.idx()]);
        }
        id
    }

    fn find_mut(&mut self, mut id: Id) -> Id {
        while self.uf[id.idx()] != id.0 {
            let grand = self.uf[self.uf[id.idx()] as usize];
            self.uf[id.idx()] = grand;
            id = Id(grand);
        }
        id
    }

    /// Number of canonical classes.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// Total e-nodes across classes.
    pub fn node_count(&self) -> usize {
        self.classes.values().map(|c| c.nodes.len()).sum()
    }

    /// Iterate canonical classes.
    pub fn classes(&self) -> impl Iterator<Item = &EClass> {
        self.classes.values()
    }

    /// Class by (canonical) id.
    pub fn class(&self, id: Id) -> &EClass {
        let canon = self.find(id);
        &self.classes[&canon]
    }

    /// Mutable class data by id.
    pub fn data_mut(&mut self, id: Id) -> &mut ClassData {
        let canon = self.find(id);
        &mut self.classes.get_mut(&canon).unwrap().data
    }

    /// Add an e-node, returning its class (hash-consed).
    pub fn add(&mut self, enode: ENode) -> Id {
        let enode = enode.canonicalize(self);
        if let Some(&id) = self.memo.get(&enode) {
            return self.find(id);
        }
        let id = Id(self.uf.len() as u32);
        self.uf.push(id.0);
        let mut data = ClassData::empty();
        if let Op::Constant(c) = &enode.op {
            if let crate::ir::ConstVal::Scalar(v) = c {
                data.constant = Some(*v);
            }
        }
        let class = EClass { id, nodes: vec![enode.clone()], parents: Vec::new(), data };
        for &child in &enode.children {
            let canon = self.find(child);
            self.classes.get_mut(&canon).unwrap().parents.push((enode.clone(), id));
        }
        self.classes.insert(id, class);
        self.memo.insert(enode, id);
        id
    }

    /// Add with analysis data attached (shape, origin, representative).
    pub fn add_with_data(
        &mut self,
        enode: ENode,
        shape: Shape,
        from_distributed: bool,
        repr: NodeId,
    ) -> Id {
        let id = self.add(enode);
        let data = self.data_mut(id);
        if data.shape.is_none() {
            data.shape = Some(shape);
        }
        if from_distributed {
            data.origin.distributed = true;
        } else {
            data.origin.baseline = true;
        }
        if data.repr.is_none() {
            data.repr = Some((from_distributed, repr));
        }
        id
    }

    /// Merge two classes. Returns the surviving canonical id.
    pub fn union(&mut self, a: Id, b: Id) -> Id {
        let a = self.find_mut(a);
        let b = self.find_mut(b);
        if a == b {
            return a;
        }
        self.merges += 1;
        // keep the class with more parents as root (union by size-ish)
        let (root, child) = if self.classes[&a].parents.len() >= self.classes[&b].parents.len()
        {
            (a, b)
        } else {
            (b, a)
        };
        self.uf[child.idx()] = root.0;
        let child_class = self.classes.remove(&child).unwrap();
        let root_class = self.classes.get_mut(&root).unwrap();
        root_class.nodes.extend(child_class.nodes);
        root_class.parents.extend(child_class.parents);
        root_class.data.merge(&child_class.data);
        self.worklist.push(root);
        root
    }

    /// Restore congruence invariants after unions (egg's `rebuild`).
    pub fn rebuild(&mut self) {
        while let Some(id) = self.worklist.pop() {
            let canon = self.find_mut(id);
            let parents = std::mem::take(&mut self.classes.get_mut(&canon).unwrap().parents);
            let mut new_parents: FxHashMap<ENode, Id> = FxHashMap::default();
            for (pnode, pclass) in parents {
                let pnode_canon = pnode.canonicalize(self);
                self.memo.remove(&pnode);
                let pclass = self.find_mut(pclass);
                if let Some(&existing) = self.memo.get(&pnode_canon) {
                    let existing = self.find_mut(existing);
                    if existing != pclass {
                        self.union(existing, pclass);
                    }
                }
                let pclass = self.find_mut(pclass);
                self.memo.insert(pnode_canon.clone(), pclass);
                new_parents.insert(pnode_canon, pclass);
            }
            let canon = self.find_mut(canon);
            self.classes
                .get_mut(&canon)
                .unwrap()
                .parents
                .extend(new_parents.into_iter());
        }
        // canonicalize stored node lists so pattern scans see canonical ids
        // (hash-based dedup: the previous format!()-based sort dominated
        // the rebuild profile — see EXPERIMENTS.md §Perf)
        let ids: Vec<Id> = self.classes.keys().copied().collect();
        for id in ids {
            let mut class = self.classes.remove(&id).unwrap();
            for n in class.nodes.iter_mut() {
                *n = n.canonicalize(self);
            }
            let mut seen: rustc_hash::FxHashSet<ENode> =
                rustc_hash::FxHashSet::default();
            class.nodes.retain(|n| seen.insert(n.clone()));
            class.id = id;
            self.classes.insert(id, class);
        }
    }

    /// Memo lookup: is there already a class containing exactly this
    /// (canonicalized) e-node? Used by the relation analysis to find the
    /// baseline partner of a distributed op.
    pub fn lookup(&self, enode: &ENode) -> Option<Id> {
        let canon = enode.canonicalize(self);
        self.memo.get(&canon).map(|&id| self.find(id))
    }

    /// True when `a` and `b` are in the same class.
    pub fn same(&self, a: Id, b: Id) -> bool {
        self.find(a) == self.find(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ConstVal, DType};

    fn leaf(eg: &mut EGraph, name: &str) -> Id {
        eg.add(ENode::new(Op::Parameter { index: 0, name: name.into() }, vec![]))
    }

    #[test]
    fn hashcons_dedups() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let a = eg.add(ENode::new(Op::Exp, vec![x]));
        let b = eg.add(ENode::new(Op::Exp, vec![x]));
        assert_eq!(a, b);
        assert_eq!(eg.class_count(), 2);
    }

    #[test]
    fn congruence_closure_merges_parents() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        let fx = eg.add(ENode::new(Op::Exp, vec![x]));
        let fy = eg.add(ENode::new(Op::Exp, vec![y]));
        assert!(!eg.same(fx, fy));
        eg.union(x, y);
        eg.rebuild();
        assert!(eg.same(fx, fy), "congruence: x=y implies f(x)=f(y)");
    }

    #[test]
    fn deep_congruence_chain() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        let mut cx = x;
        let mut cy = y;
        for _ in 0..10 {
            cx = eg.add(ENode::new(Op::Neg, vec![cx]));
            cy = eg.add(ENode::new(Op::Neg, vec![cy]));
        }
        eg.union(x, y);
        eg.rebuild();
        assert!(eg.same(cx, cy));
    }

    #[test]
    fn union_is_idempotent() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x");
        let y = leaf(&mut eg, "y");
        eg.union(x, y);
        let m = eg.merges;
        eg.union(x, y);
        assert_eq!(eg.merges, m);
    }

    #[test]
    fn constant_data_tracked() {
        let mut eg = EGraph::new();
        let c = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(2.5)), vec![]));
        assert_eq!(eg.class(c).data.constant, Some(2.5));
    }

    #[test]
    fn origin_merges() {
        let mut eg = EGraph::new();
        let x = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "b".into() }, vec![]),
            Shape::scalar(DType::F32),
            false,
            NodeId(0),
        );
        let y = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "d".into() }, vec![]),
            Shape::scalar(DType::F32),
            true,
            NodeId(0),
        );
        eg.union(x, y);
        eg.rebuild();
        let o = eg.class(x).data.origin;
        assert!(o.baseline && o.distributed);
    }
}
