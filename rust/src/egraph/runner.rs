//! Saturation runner with node/iteration limits.
//!
//! Naively constructing e-graphs "easily leads to exponential blow up in
//! time and memory usage" (paper §4) — the runner enforces the budgets
//! that graph partitioning makes sufficient: per-layer subgraphs saturate
//! in a handful of iterations well under the limits.

use super::{EGraph, Rewrite};

/// Saturation budgets.
#[derive(Clone, Copy, Debug)]
pub struct RunLimits {
    /// Maximum rewrite iterations.
    pub max_iters: usize,
    /// Abort when the e-graph exceeds this many e-nodes.
    pub max_nodes: usize,
}

impl Default for RunLimits {
    fn default() -> Self {
        RunLimits { max_iters: 24, max_nodes: 400_000 }
    }
}

/// Why the runner stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Fixpoint: no rule changed anything.
    Saturated,
    /// Iteration budget exhausted.
    IterLimit,
    /// Node budget exhausted (the "insufficient resources" outcome the
    /// paper reports for unpartitioned full-model rewriting).
    NodeLimit,
}

/// Saturation outcome.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Iterations executed.
    pub iterations: usize,
    /// Total rule applications (unions performed).
    pub applications: usize,
    /// Final e-node count.
    pub nodes: usize,
    /// Final class count.
    pub classes: usize,
    /// Why we stopped.
    pub stop: StopReason,
}

/// Runs a rule set to saturation under limits.
pub struct Runner<'a> {
    rules: &'a [Box<dyn Rewrite>],
    limits: RunLimits,
}

impl<'a> Runner<'a> {
    /// New runner over `rules`.
    pub fn new(rules: &'a [Box<dyn Rewrite>], limits: RunLimits) -> Self {
        Runner { rules, limits }
    }

    /// Saturate `eg`.
    pub fn run(&self, eg: &mut EGraph) -> RunReport {
        let mut applications = 0;
        let mut iterations = 0;
        let stop = loop {
            if iterations >= self.limits.max_iters {
                break StopReason::IterLimit;
            }
            iterations += 1;
            let mut changed = 0;
            for rule in self.rules {
                changed += rule.apply(eg);
                eg.rebuild();
                if eg.node_count() > self.limits.max_nodes {
                    break;
                }
            }
            applications += changed;
            if eg.node_count() > self.limits.max_nodes {
                break StopReason::NodeLimit;
            }
            if changed == 0 {
                break StopReason::Saturated;
            }
        };
        RunReport {
            iterations,
            applications,
            nodes: eg.node_count(),
            classes: eg.class_count(),
            stop,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{default_rules, ENode};
    use crate::ir::{DType, Op, Shape};

    #[test]
    fn saturates_transpose_tower() {
        let mut eg = EGraph::new();
        let x = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "x".into() }, vec![]),
            Shape::new(DType::F32, vec![2, 3, 4]),
            false,
            crate::ir::NodeId(0),
        );
        let mut cur = x;
        let mut dims = vec![2i64, 3, 4];
        // 6 rotations of rank-3 = identity twice
        for i in 0..6u32 {
            dims.rotate_left(1);
            cur = eg.add_with_data(
                ENode::new(Op::Transpose { perm: vec![1, 2, 0] }, vec![cur]),
                Shape::new(DType::F32, dims.clone()),
                false,
                crate::ir::NodeId(i + 1),
            );
        }
        let rules = default_rules();
        let report = Runner::new(&rules, RunLimits::default()).run(&mut eg);
        assert_eq!(report.stop, StopReason::Saturated);
        assert!(eg.same(x, cur), "rotating rank-3 six times is the identity");
    }

    #[test]
    fn node_limit_respected() {
        let mut eg = EGraph::new();
        let x = eg.add(ENode::new(Op::Parameter { index: 0, name: "x".into() }, vec![]));
        let y = eg.add(ENode::new(Op::Parameter { index: 1, name: "y".into() }, vec![]));
        let mut cur = eg.add(ENode::new(Op::Add, vec![x, y]));
        for _ in 0..50 {
            cur = eg.add(ENode::new(Op::Add, vec![cur, y]));
        }
        let rules = default_rules();
        let limits = RunLimits { max_iters: 100, max_nodes: 10 };
        let report = Runner::new(&rules, limits).run(&mut eg);
        assert_eq!(report.stop, StopReason::NodeLimit);
    }
}
