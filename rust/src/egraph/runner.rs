//! Saturation runner: indexed incremental e-matching, a backoff rule
//! scheduler, one rebuild per iteration, and node/iteration limits.
//!
//! Naively constructing e-graphs "easily leads to exponential blow up in
//! time and memory usage" (paper §4) — the runner enforces the budgets
//! that graph partitioning makes sufficient, and keeps the per-iteration
//! cost proportional to what actually changed:
//!
//! * **Indexed incremental matching** — each rule holds a
//!   [`MatchCursor`] into the e-graph's per-kind match logs, so an
//!   iteration only offers it classes created or changed since the rule
//!   last ran (the naive full rescan survives as [`MatchMode::Naive`] for
//!   differential testing and the bench comparison).
//! * **One rebuild per iteration** — congruence restoration is deferred
//!   to a single [`EGraph::rebuild`] after the rule pass instead of one
//!   rebuild per rule (egg's deferred-rebuild design).
//! * **Backoff scheduling** — a rule whose candidate set exceeds
//!   [`RunLimits::match_limit`] in one iteration is banned for a doubling
//!   number of iterations, throttling match-heavy, low-yield rules.

use super::engine::MatchCursor;
use super::{EGraph, Rewrite};
use std::time::{Duration, Instant};

/// E-matching strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchMode {
    /// Index + per-rule dirty cursors (the default).
    Indexed,
    /// Full rescan of every class by every rule every iteration — the
    /// pre-index behavior, kept behind the `SCALIFY_NAIVE_MATCH=1`
    /// environment toggle for differential tests and benchmarks.
    Naive,
}

impl MatchMode {
    /// [`MatchMode::Naive`] when `SCALIFY_NAIVE_MATCH` is `1`/`true`,
    /// else [`MatchMode::Indexed`].
    pub fn from_env() -> MatchMode {
        match std::env::var("SCALIFY_NAIVE_MATCH") {
            Ok(v) if v == "1" || v.eq_ignore_ascii_case("true") => MatchMode::Naive,
            _ => MatchMode::Indexed,
        }
    }
}

/// Saturation budgets and matching strategy.
#[derive(Clone, Copy, Debug)]
pub struct RunLimits {
    /// Maximum rewrite iterations.
    pub max_iters: usize,
    /// Abort when the e-graph exceeds this many e-nodes (enforced once
    /// per iteration, at the rebuild point).
    pub max_nodes: usize,
    /// Matching strategy (see [`MatchMode`]).
    pub match_mode: MatchMode,
    /// Backoff threshold: a rule offered more than this many candidates
    /// in one iteration is banned for a doubling number of iterations.
    /// `usize::MAX` disables the scheduler.
    pub match_limit: usize,
    /// Initial ban length for the backoff scheduler.
    pub ban_length: usize,
    /// Absolute deadline, checked at the top of every iteration, so a
    /// blown verify deadline stops within one rewrite iteration instead
    /// of overshooting to the next layer boundary.
    pub deadline: Option<Instant>,
}

impl Default for RunLimits {
    fn default() -> Self {
        RunLimits {
            max_iters: 24,
            max_nodes: 400_000,
            match_mode: MatchMode::from_env(),
            match_limit: 4096,
            ban_length: 2,
            deadline: None,
        }
    }
}

/// Why the runner stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Fixpoint: no rule changed anything.
    Saturated,
    /// Iteration budget exhausted.
    IterLimit,
    /// Node budget exhausted (the "insufficient resources" outcome the
    /// paper reports for unpartitioned full-model rewriting).
    NodeLimit,
    /// The [`RunLimits::deadline`] passed; the e-graph is left in a
    /// consistent (rebuilt) state but saturation is incomplete, so any
    /// equivalence *not yet* proven stays unproven — callers degrade
    /// rather than report a discrepancy.
    DeadlineExceeded,
}

/// Per-rule saturation counters (threaded into `LayerReport` and the
/// scale bench).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RuleStat {
    /// Rule name.
    pub name: String,
    /// E-nodes examined while collecting this rule's candidates — the
    /// "e-match work" metric the indexed matcher minimizes.
    pub matches_tried: usize,
    /// Candidate `(class, node)` pairs offered to the rule.
    pub matches: usize,
    /// Unions the rule performed.
    pub applications: usize,
    /// Wall time spent matching + applying.
    pub time: Duration,
    /// Iterations the backoff scheduler skipped this rule.
    pub banned_iters: usize,
}

impl RuleStat {
    fn merge(&mut self, other: &RuleStat) {
        self.matches_tried += other.matches_tried;
        self.matches += other.matches;
        self.applications += other.applications;
        self.time += other.time;
        self.banned_iters += other.banned_iters;
    }
}

/// Sum per-rule stats across runs (entries are matched by rule name; used
/// by the layer verifier to aggregate its fixpoint rounds).
pub fn merge_rule_stats(into: &mut Vec<RuleStat>, from: &[RuleStat]) {
    for f in from {
        match into.iter_mut().find(|s| s.name == f.name) {
            Some(s) => s.merge(f),
            None => into.push(f.clone()),
        }
    }
}

/// Saturation outcome.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Iterations executed.
    pub iterations: usize,
    /// Total rule applications (unions performed).
    pub applications: usize,
    /// Final e-node count.
    pub nodes: usize,
    /// Final class count.
    pub classes: usize,
    /// Why we stopped.
    pub stop: StopReason,
    /// Total e-nodes examined during candidate collection.
    pub matches_tried: usize,
    /// How far past `max_nodes` the final iteration landed (0 unless the
    /// stop reason is [`StopReason::NodeLimit`]).
    pub node_overshoot: usize,
    /// Per-rule counters, in rule order.
    pub rules: Vec<RuleStat>,
}

/// Runs a rule set to saturation under limits. The runner is stateful:
/// per-rule match cursors and backoff bans persist across [`Runner::run`]
/// calls, so a layer verifier's relation-fixpoint rounds only re-match
/// what the relation pass changed in between.
pub struct Runner<'a> {
    rules: &'a [Box<dyn Rewrite>],
    limits: RunLimits,
    cursors: Vec<MatchCursor>,
    banned_until: Vec<usize>,
    times_banned: Vec<u32>,
    clock: usize,
}

impl<'a> Runner<'a> {
    /// New runner over `rules`.
    pub fn new(rules: &'a [Box<dyn Rewrite>], limits: RunLimits) -> Self {
        Runner {
            rules,
            limits,
            cursors: rules.iter().map(|_| MatchCursor::new()).collect(),
            banned_until: vec![0; rules.len()],
            times_banned: vec![0; rules.len()],
            clock: 0,
        }
    }

    /// Saturate `eg`.
    pub fn run(&mut self, eg: &mut EGraph) -> RunReport {
        let indexed = self.limits.match_mode == MatchMode::Indexed;
        let mut stats: Vec<RuleStat> = self
            .rules
            .iter()
            .map(|r| RuleStat { name: r.name().to_string(), ..RuleStat::default() })
            .collect();
        let mut applications = 0;
        let mut iterations = 0;
        let mut matches_tried = 0;
        let mut node_overshoot = 0;
        let stop = loop {
            if let Some(dl) = self.limits.deadline {
                if Instant::now() >= dl {
                    break StopReason::DeadlineExceeded;
                }
            }
            if iterations >= self.limits.max_iters {
                break StopReason::IterLimit;
            }
            iterations += 1;
            self.clock += 1;
            let mut changed = 0;
            let mut any_banned = false;
            let mut exceeded = false;
            for ri in 0..self.rules.len() {
                if indexed && self.banned_until[ri] > self.clock {
                    any_banned = true;
                    stats[ri].banned_iters += 1;
                    continue;
                }
                let t0 = Instant::now();
                // per-rule e-match/apply span; inert (one atomic load)
                // unless a `--trace` run is recording
                let mut rspan = crate::obs::span("rule", self.rules[ri].name());
                let mut tried = 0usize;
                let roots = self.rules[ri].roots();
                let cands = if indexed {
                    eg.candidates(roots, &mut self.cursors[ri], &mut tried)
                } else {
                    eg.candidates_naive(roots, &mut tried)
                };
                let n = self.rules[ri].apply(eg, &cands);
                changed += n;
                matches_tried += tried;
                stats[ri].matches_tried += tried;
                stats[ri].matches += cands.len();
                stats[ri].applications += n;
                stats[ri].time += t0.elapsed();
                rspan.attr("matches_tried", tried as u64);
                rspan.attr("matches", cands.len() as u64);
                rspan.attr("applications", n as u64);
                if indexed && cands.len() > self.limits.match_limit {
                    let len = self.limits.ban_length.max(1) << self.times_banned[ri].min(16);
                    self.banned_until[ri] = self.clock + len;
                    self.times_banned[ri] += 1;
                }
                if eg.node_count() > self.limits.max_nodes {
                    exceeded = true;
                    break;
                }
            }
            applications += changed;
            eg.rebuild();
            // the node budget is enforced here, at the (single) rebuild
            // point, and the overshoot is reported instead of hidden
            if eg.node_count() > self.limits.max_nodes {
                node_overshoot = eg.node_count() - self.limits.max_nodes;
                break StopReason::NodeLimit;
            }
            if exceeded {
                // the mid-pass budget scare resolved at rebuild (duplicate
                // e-nodes folded back under the limit); the rules we
                // skipped run next iteration — this is NOT saturation
                continue;
            }
            if changed == 0 {
                if !any_banned {
                    break StopReason::Saturated;
                }
                // only banned rules have pending work: fast-forward the
                // scheduler clock to the next ban expiry instead of
                // idling away the iteration budget
                let mut next: Option<usize> = None;
                for ri in 0..self.rules.len() {
                    if self.banned_until[ri] > self.clock {
                        next = Some(match next {
                            Some(m) => m.min(self.banned_until[ri]),
                            None => self.banned_until[ri],
                        });
                    }
                }
                if let Some(next) = next {
                    self.clock = next;
                }
            }
        };
        RunReport {
            iterations,
            applications,
            nodes: eg.node_count(),
            classes: eg.class_count(),
            stop,
            matches_tried,
            node_overshoot,
            rules: stats,
        }
    }
}

// The parallel cold pass ships whole per-layer verifications to pool
// workers: each job builds its own e-graph (arena-style — every e-node,
// class and match log lives in the job's `EGraph` and is dropped
// wholesale with it, so nothing is shared or freed piecemeal across
// threads), runs a `Runner` over the session's shared rule set, and
// sends the `RunReport`-derived outcome back. These assertions pin the
// Send/Sync story at compile time so a future `Rc`/`RefCell` inside the
// engine fails here, not in a distant `pool.run_all` bound.
#[allow(dead_code)]
fn assert_engine_crosses_threads() {
    fn assert_send<T: Send>() {}
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send::<EGraph>();
    assert_send::<RunReport>();
    assert_send::<Runner<'static>>();
    assert_send_sync::<super::RuleSet>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{default_rules, ENode};
    use crate::ir::{DType, Op, Shape};

    fn limits(mode: MatchMode) -> RunLimits {
        RunLimits { match_mode: mode, ..RunLimits::default() }
    }

    fn transpose_tower(eg: &mut EGraph) -> (crate::egraph::Id, crate::egraph::Id) {
        let x = eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: "x".into() }, vec![]),
            Shape::new(DType::F32, vec![2, 3, 4]),
            false,
            crate::ir::NodeId(0),
        );
        let mut cur = x;
        let mut dims = vec![2i64, 3, 4];
        // 6 rotations of rank-3 = identity twice
        for i in 0..6u32 {
            dims.rotate_left(1);
            cur = eg.add_with_data(
                ENode::new(Op::Transpose { perm: vec![1, 2, 0] }, vec![cur]),
                Shape::new(DType::F32, dims.clone()),
                false,
                crate::ir::NodeId(i + 1),
            );
        }
        (x, cur)
    }

    #[test]
    fn saturates_transpose_tower() {
        let mut eg = EGraph::new();
        let (x, cur) = transpose_tower(&mut eg);
        let rules = default_rules();
        let report = Runner::new(&rules, limits(MatchMode::Indexed)).run(&mut eg);
        assert_eq!(report.stop, StopReason::Saturated);
        assert!(eg.same(x, cur), "rotating rank-3 six times is the identity");
        assert!(report.matches_tried > 0);
        assert_eq!(report.rules.len(), rules.len());
    }

    #[test]
    fn node_limit_respected_with_overshoot() {
        let mut eg = EGraph::new();
        let x = eg.add(ENode::new(Op::Parameter { index: 0, name: "x".into() }, vec![]));
        let y = eg.add(ENode::new(Op::Parameter { index: 1, name: "y".into() }, vec![]));
        let mut cur = eg.add(ENode::new(Op::Add, vec![x, y]));
        for _ in 0..50 {
            cur = eg.add(ENode::new(Op::Add, vec![cur, y]));
        }
        let rules = default_rules();
        let lim = RunLimits { max_iters: 100, max_nodes: 10, ..RunLimits::default() };
        let report = Runner::new(&rules, lim).run(&mut eg);
        assert_eq!(report.stop, StopReason::NodeLimit);
        assert_eq!(report.node_overshoot, report.nodes - 10);
        assert!(report.node_overshoot > 0);
    }

    #[test]
    fn indexed_and_naive_agree_and_indexed_tries_less() {
        let mut eg_i = EGraph::new();
        let (xi, ci) = transpose_tower(&mut eg_i);
        let mut eg_n = EGraph::new();
        let (xn, cn) = transpose_tower(&mut eg_n);
        let rules = default_rules();
        let ri = Runner::new(&rules, limits(MatchMode::Indexed)).run(&mut eg_i);
        let rn = Runner::new(&rules, limits(MatchMode::Naive)).run(&mut eg_n);
        assert_eq!(ri.stop, rn.stop);
        assert_eq!(eg_i.same(xi, ci), eg_n.same(xn, cn));
        assert_eq!(eg_i.class_count(), eg_n.class_count());
        assert_eq!(eg_i.node_count(), eg_n.node_count());
        assert!(
            ri.matches_tried * 3 <= rn.matches_tried,
            "indexed matching should do >=3x less e-match work: {} vs {}",
            ri.matches_tried,
            rn.matches_tried
        );
    }

    #[test]
    fn backoff_bans_match_heavy_rules() {
        let mut eg = EGraph::new();
        let (x, cur) = transpose_tower(&mut eg);
        // match_limit 0: every rule that sees any candidate gets banned
        let lim = RunLimits {
            match_limit: 0,
            ban_length: 1,
            max_iters: 500,
            ..limits(MatchMode::Indexed)
        };
        let rules = default_rules();
        let report = Runner::new(&rules, lim).run(&mut eg);
        // throttled rules still converge (bans expire), just later
        assert_eq!(report.stop, StopReason::Saturated);
        assert!(eg.same(x, cur));
        assert!(
            report.rules.iter().any(|r| r.banned_iters > 0),
            "at least one rule should have been throttled"
        );
    }

    #[test]
    fn cursors_persist_across_runs() {
        let mut eg = EGraph::new();
        let (_, _) = transpose_tower(&mut eg);
        let rules = default_rules();
        let mut runner = Runner::new(&rules, limits(MatchMode::Indexed));
        let first = runner.run(&mut eg);
        // nothing changed since: a second run re-matches (almost) nothing
        let second = runner.run(&mut eg);
        assert_eq!(second.stop, StopReason::Saturated);
        assert!(
            second.matches_tried <= first.matches_tried / 2,
            "stateful runner must not rescan a saturated e-graph: {} vs {}",
            second.matches_tried,
            first.matches_tried
        );
    }

    #[test]
    fn merge_rule_stats_sums_by_name() {
        let a = vec![RuleStat { name: "r".into(), matches: 2, ..RuleStat::default() }];
        let mut into = Vec::new();
        merge_rule_stats(&mut into, &a);
        merge_rule_stats(&mut into, &a);
        assert_eq!(into.len(), 1);
        assert_eq!(into[0].matches, 4);
    }
}
