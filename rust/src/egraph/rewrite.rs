//! Rewrite rules (the paper's reusable rule templates, §4/§6).
//!
//! Rules are *programmatic appliers*: each declares the operator kinds it
//! can match at the root of its pattern ([`Rewrite::roots`]) and is fed
//! `(class, node)` candidates by the runner's matcher — incrementally
//! (only classes created or changed since the rule last ran) or naively
//! (full rescan, kept for differential testing). This mirrors how the
//! paper's 25 meta-rules are parameterized templates ("polymorphic over
//! operator types") rather than fixed syntactic patterns. Every rule is
//! semantics-preserving, which is what keeps the verifier sound: a union
//! can only ever merge terms a rule proved equal.

use super::engine::{kind_bits, CNode, EGraph, ENode, Id, OpKind};
use crate::ir::{ConstVal, Op};
use rustc_hash::FxHashSet;

/// A rewrite rule.
pub trait Rewrite: Send + Sync {
    /// Rule name (for reports).
    fn name(&self) -> &'static str;
    /// Bitmask of [`OpKind`]s the rule matches at the root of its pattern
    /// (build with [`kind_bits`]). The matcher only feeds it candidates
    /// of these kinds.
    fn roots(&self) -> u64;
    /// Apply over the supplied candidates, emitting unions / new e-nodes;
    /// return the number of unions performed.
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize;
}

fn compose_perm(outer: &[usize], inner: &[usize]) -> Vec<usize> {
    // transpose(transpose(x, inner), outer): result dim i = inner[outer[i]]
    outer.iter().map(|&o| inner[o]).collect()
}

fn is_identity(perm: &[usize]) -> bool {
    perm.iter().enumerate().all(|(i, &p)| i == p)
}

/// `transpose(x, id) = x` and `transpose(transpose(x, p), q) = transpose(x, p∘q)`.
struct TransposeFusion;
impl Rewrite for TransposeFusion {
    fn name(&self) -> &'static str {
        "transpose-fusion"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Transpose])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        for (cls, node) in cands {
            let cls = *cls;
            let perm = match eg.op(node.op) {
                Op::Transpose { perm } => perm.clone(),
                _ => continue,
            };
            let child0 = node.children()[0];
            if is_identity(&perm) {
                let child = eg.find(child0);
                if !eg.same(cls, child) {
                    eg.union(cls, child);
                    n += 1;
                }
                continue;
            }
            // look one level down for another transpose
            let inner_nodes: Vec<CNode> = eg.class(child0).nodes.clone();
            for inner in inner_nodes {
                let composed = match eg.op(inner.op) {
                    Op::Transpose { perm: ip } => compose_perm(&perm, ip),
                    _ => continue,
                };
                let inner_child = inner.children()[0];
                let new = if is_identity(&composed) {
                    eg.find(inner_child)
                } else {
                    let shape = eg.class(cls).data.shape.clone();
                    let id = eg.add(ENode::new(
                        Op::Transpose { perm: composed },
                        vec![inner_child],
                    ));
                    // only touch data_mut (which dirty-marks the class)
                    // when there is actually something to write
                    if let Some(s) = shape {
                        if eg.class(id).data.shape.is_none() {
                            eg.data_mut(id).shape = Some(s);
                        }
                    }
                    id
                };
                if !eg.same(cls, new) {
                    eg.union(cls, new);
                    n += 1;
                }
            }
        }
        n
    }
}

/// `reshape(x) = x` when shapes match; `reshape(reshape(x)) = reshape(x)`.
struct ReshapeFusion;
impl Rewrite for ReshapeFusion {
    fn name(&self) -> &'static str {
        "reshape-fusion"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Reshape])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        for (cls, node) in cands {
            let cls = *cls;
            let child = eg.find(node.children()[0]);
            let out_shape = eg.class(cls).data.shape.clone();
            let in_shape = eg.class(child).data.shape.clone();
            if let (Some(o), Some(i)) = (&out_shape, &in_shape) {
                if o.dims == i.dims {
                    if !eg.same(cls, child) {
                        eg.union(cls, child);
                        n += 1;
                    }
                    continue;
                }
            }
            // reshape(reshape(x)) -> reshape(x) (same final shape)
            let dims = match eg.op(node.op) {
                Op::Reshape { dims } => dims.clone(),
                _ => continue,
            };
            let inner_nodes: Vec<CNode> = eg.class(child).nodes.clone();
            for inner in inner_nodes {
                if matches!(eg.op(inner.op), Op::Reshape { .. }) {
                    let id = eg.add(ENode::new(
                        Op::Reshape { dims: dims.clone() },
                        vec![inner.children()[0]],
                    ));
                    if let Some(s) = out_shape.clone() {
                        if eg.class(id).data.shape.is_none() {
                            eg.data_mut(id).shape = Some(s);
                        }
                    }
                    if !eg.same(cls, id) {
                        eg.union(cls, id);
                        n += 1;
                    }
                }
            }
        }
        n
    }
}

/// `convert(x, t) = x` when x already has dtype t; collapse convert chains
/// that cannot lose precision.
struct ConvertElim;
impl Rewrite for ConvertElim {
    fn name(&self) -> &'static str {
        "convert-elim"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Convert])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        for (cls, node) in cands {
            let cls = *cls;
            let to = match eg.op(node.op) {
                Op::Convert { to } => *to,
                _ => continue,
            };
            let child = eg.find(node.children()[0]);
            let child_dtype = eg.class(child).data.shape.as_ref().map(|s| s.dtype);
            if child_dtype == Some(to) {
                if !eg.same(cls, child) {
                    eg.union(cls, child);
                    n += 1;
                }
                continue;
            }
            // convert(convert(x, t1), t2): collapse only when the inner
            // conversion does not truncate (mantissa(t1) >= mantissa(src)),
            // otherwise the chain is *not* equal to convert(x, t2) — this is
            // exactly the precision-bug pattern we must not erase.
            let inner_nodes: Vec<CNode> = eg.class(child).nodes.clone();
            for inner in inner_nodes {
                let t1 = match eg.op(inner.op) {
                    Op::Convert { to: t1 } => *t1,
                    _ => continue,
                };
                let inner_child = inner.children()[0];
                let src = eg.class(inner_child).data.shape.as_ref().map(|s| s.dtype);
                if let Some(src) = src {
                    if t1.mantissa_bits() >= src.mantissa_bits()
                        && t1.is_float()
                        && src.is_float()
                    {
                        let id = eg.add(ENode::new(Op::Convert { to }, vec![inner_child]));
                        if !eg.same(cls, id) {
                            eg.union(cls, id);
                            n += 1;
                        }
                    }
                }
            }
        }
        n
    }
}

/// Commutativity of add/mul/max/min.
struct Commute;
impl Rewrite for Commute {
    fn name(&self) -> &'static str {
        "commute"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Add, OpKind::Mul, OpKind::Max, OpKind::Min])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        for (cls, node) in cands {
            let cls = *cls;
            if node.children().len() != 2 {
                continue;
            }
            let op = eg.op(node.op).clone();
            if !op.is_commutative() {
                continue;
            }
            let flipped = ENode::new(op, vec![node.children()[1], node.children()[0]]);
            let id = eg.add(flipped);
            if !eg.same(cls, id) {
                eg.union(cls, id);
                n += 1;
            }
        }
        n
    }
}

/// Scalar constant folding for unary/binary arithmetic on scalar constants.
struct ConstFold;
impl Rewrite for ConstFold {
    fn name(&self) -> &'static str {
        "const-fold"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[
            OpKind::Add,
            OpKind::Sub,
            OpKind::Mul,
            OpKind::Div,
            OpKind::Max,
            OpKind::Min,
            OpKind::Pow,
            OpKind::Neg,
            OpKind::Exp,
            OpKind::Log,
            OpKind::Sqrt,
            OpKind::Rsqrt,
            OpKind::Abs,
        ])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut pending: Vec<(Id, f64)> = Vec::new();
        let mut done: FxHashSet<Id> = FxHashSet::default();
        for (cls, node) in cands {
            let cls = eg.find(*cls);
            if done.contains(&cls) || eg.class(cls).data.constant.is_some() {
                continue;
            }
            let cv = |i: usize| eg.class(node.children()[i]).data.constant;
            let v = match eg.op(node.op) {
                Op::Add => cv(0).zip(cv(1)).map(|(a, b)| a + b),
                Op::Sub => cv(0).zip(cv(1)).map(|(a, b)| a - b),
                Op::Mul => cv(0).zip(cv(1)).map(|(a, b)| a * b),
                Op::Div => cv(0).zip(cv(1)).map(|(a, b)| a / b),
                Op::Max => cv(0).zip(cv(1)).map(|(a, b)| a.max(b)),
                Op::Min => cv(0).zip(cv(1)).map(|(a, b)| a.min(b)),
                Op::Pow => cv(0).zip(cv(1)).map(|(a, b)| a.powf(b)),
                Op::Neg => cv(0).map(|a| -a),
                Op::Exp => cv(0).map(f64::exp),
                Op::Log => cv(0).map(f64::ln),
                Op::Sqrt => cv(0).map(f64::sqrt),
                Op::Rsqrt => cv(0).map(|a| 1.0 / a.sqrt()),
                Op::Abs => cv(0).map(f64::abs),
                _ => None,
            };
            if let Some(v) = v {
                pending.push((cls, v));
                done.insert(cls);
            }
        }
        let n = pending.len();
        for (cls, v) in pending {
            let c = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(v)), vec![]));
            eg.union(cls, c);
            let canon = eg.find(cls);
            eg.data_mut(canon).constant = Some(v);
        }
        n
    }
}

/// `div(x, bcast(c)) = mul(x, bcast(1/c))` for scalar constant c — the
/// softmax-normalization difference between baseline and optimized graphs.
struct DivToMulRecip;
impl Rewrite for DivToMulRecip {
    fn name(&self) -> &'static str {
        "div-to-mul-recip"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Div])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        for (cls, node) in cands {
            let cls = *cls;
            let lhs = node.children()[0];
            let rhs = node.children()[1];
            // rhs must be broadcast(const c) or const c
            let rhs_nodes: Vec<CNode> = eg.class(rhs).nodes.clone();
            for rn in rhs_nodes {
                let (bc_mapped, c) = match eg.op(rn.op) {
                    Op::Broadcast { mapped, .. } => {
                        let m = mapped.clone();
                        let c = eg.class(rn.children()[0]).data.constant;
                        (Some(m), c)
                    }
                    Op::Constant(ConstVal::Scalar(v)) => (None, Some(*v)),
                    _ => (None, None),
                };
                let Some(c) = c else { continue };
                if c == 0.0 {
                    continue;
                }
                let recip =
                    eg.add(ENode::new(Op::Constant(ConstVal::Scalar(1.0 / c)), vec![]));
                let rhs_shape = eg.class(rhs).data.shape.clone();
                let recip_full = match (&bc_mapped, rhs_shape) {
                    (Some(mapped), Some(shape)) => {
                        let id = eg.add(ENode::new(
                            Op::Broadcast { mapped: mapped.clone(), dims: shape.dims.clone() },
                            vec![recip],
                        ));
                        if eg.class(id).data.shape.is_none() {
                            eg.data_mut(id).shape = Some(shape);
                        }
                        id
                    }
                    _ => recip,
                };
                let mul = eg.add(ENode::new(Op::Mul, vec![lhs, recip_full]));
                if !eg.same(cls, mul) {
                    eg.union(cls, mul);
                    n += 1;
                }
            }
        }
        n
    }
}

/// `concat(slice(x, 0..k), slice(x, k..n), d) = x` — full-cover slice
/// reassembly, the pattern fine-grained slicing analysis relies on.
struct SliceReassembly;
impl Rewrite for SliceReassembly {
    fn name(&self) -> &'static str {
        "slice-reassembly"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Concat])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        'outer: for (cls, node) in cands {
            let cls = *cls;
            let dim = match eg.op(node.op) {
                Op::Concat { dim } => *dim,
                _ => continue,
            };
            // each child must be slice(x, ...) of the same x along `dim`,
            // contiguous from 0 to the full size
            let mut src: Option<Id> = None;
            let mut cursor = 0i64;
            for &child in node.children() {
                let mut matched = false;
                for cn in eg.class(child).nodes.clone() {
                    let slice = match eg.op(cn.op) {
                        Op::Slice { starts, limits, strides } => {
                            Some((starts.clone(), limits.clone(), strides.clone()))
                        }
                        _ => None,
                    };
                    let Some((starts, limits, strides)) = slice else { continue };
                    if strides.iter().any(|&s| s != 1) {
                        continue;
                    }
                    // full range on all dims except `dim`
                    let in_shape = match &eg.class(cn.children()[0]).data.shape {
                        Some(s) => s.clone(),
                        None => continue,
                    };
                    let full_elsewhere = starts.iter().zip(&limits).enumerate().all(
                        |(i, (&s, &l))| i == dim || (s == 0 && l == in_shape.dims[i]),
                    );
                    if !full_elsewhere || starts[dim] != cursor {
                        continue;
                    }
                    let x = eg.find(cn.children()[0]);
                    if let Some(prev) = src {
                        if prev != x {
                            continue;
                        }
                    }
                    src = Some(x);
                    cursor = limits[dim];
                    matched = true;
                    break;
                }
                if !matched {
                    continue 'outer;
                }
            }
            if let Some(x) = src {
                let full = eg.class(x).data.shape.as_ref().map(|s| s.dims[dim]);
                if full == Some(cursor) && !eg.same(cls, x) {
                    eg.union(cls, x);
                    n += 1;
                }
            }
        }
        n
    }
}

/// `slice(x, full range) = x`.
struct FullSliceElim;
impl Rewrite for FullSliceElim {
    fn name(&self) -> &'static str {
        "full-slice-elim"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Slice])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        for (cls, node) in cands {
            let cls = *cls;
            let full = {
                let Op::Slice { starts, limits, strides } = eg.op(node.op) else {
                    continue;
                };
                let child = eg.find(node.children()[0]);
                let Some(in_shape) = &eg.class(child).data.shape else { continue };
                strides.iter().all(|&s| s == 1)
                    && starts.iter().all(|&s| s == 0)
                    && limits.iter().zip(&in_shape.dims).all(|(&l, &d)| l == d)
            };
            let child = eg.find(node.children()[0]);
            if full && !eg.same(cls, child) {
                eg.union(cls, child);
                n += 1;
            }
        }
        n
    }
}

/// `x + bcast(0) = x`, `x * bcast(1) = x`.
struct IdentityElim;
impl Rewrite for IdentityElim {
    fn name(&self) -> &'static str {
        "identity-elim"
    }
    fn roots(&self) -> u64 {
        kind_bits(&[OpKind::Add, OpKind::Mul])
    }
    fn apply(&self, eg: &mut EGraph, cands: &[(Id, CNode)]) -> usize {
        let mut n = 0;
        for (cls, node) in cands {
            let cls = *cls;
            if node.children().len() != 2 {
                continue;
            }
            let ident = match eg.op(node.op) {
                Op::Add => 0.0,
                Op::Mul => 1.0,
                _ => continue,
            };
            for (keep, other) in
                [(node.children()[0], node.children()[1]), (node.children()[1], node.children()[0])]
            {
                let other_is_ident = eg.class(other).data.constant == Some(ident)
                    || eg.class(other).nodes.iter().any(|cn| {
                        matches!(eg.op(cn.op), Op::Broadcast { .. })
                            && eg.class(cn.children()[0]).data.constant == Some(ident)
                    });
                if other_is_ident && !eg.same(cls, keep) {
                    eg.union(cls, keep);
                    n += 1;
                    break;
                }
            }
        }
        n
    }
}

/// The default rule set registered by the verifier.
pub fn default_rules() -> Vec<Box<dyn Rewrite>> {
    vec![
        Box::new(TransposeFusion),
        Box::new(ReshapeFusion),
        Box::new(ConvertElim),
        Box::new(Commute),
        Box::new(ConstFold),
        Box::new(DivToMulRecip),
        Box::new(SliceReassembly),
        Box::new(FullSliceElim),
        Box::new(IdentityElim),
    ]
}

/// A compiled rewrite-template set, built once and shared (via `Arc`)
/// across every layer verification a [`crate::verifier::Session`] runs —
/// the paper's "reusable rule templates" made literal: template
/// construction is paid once per session, not once per `verify` call.
pub struct RuleSet {
    rules: Vec<Box<dyn Rewrite>>,
}

impl RuleSet {
    /// Compile the default template set.
    pub fn compile() -> RuleSet {
        RuleSet { rules: default_rules() }
    }

    /// Compile a custom template set.
    pub fn from_rules(rules: Vec<Box<dyn Rewrite>>) -> RuleSet {
        RuleSet { rules }
    }

    /// The compiled templates, in application order.
    pub fn rules(&self) -> &[Box<dyn Rewrite>] {
        &self.rules
    }

    /// Number of templates.
    pub fn len(&self) -> usize {
        self.rules.len()
    }

    /// True when no templates are registered.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

impl Default for RuleSet {
    fn default() -> RuleSet {
        RuleSet::compile()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::{RunLimits, Runner};
    use crate::ir::{DType, Shape};

    fn leaf(eg: &mut EGraph, name: &str, dims: &[i64]) -> Id {
        eg.add_with_data(
            ENode::new(Op::Parameter { index: 0, name: name.into() }, vec![]),
            Shape::new(DType::F32, dims.to_vec()),
            false,
            crate::ir::NodeId(0),
        )
    }

    fn saturate(eg: &mut EGraph) {
        let rules = default_rules();
        let mut runner = Runner::new(&rules, RunLimits::default());
        runner.run(eg);
    }

    #[test]
    fn transpose_involution() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[2, 3]);
        let t1 = eg.add_with_data(
            ENode::new(Op::Transpose { perm: vec![1, 0] }, vec![x]),
            Shape::new(DType::F32, vec![3, 2]),
            false,
            crate::ir::NodeId(1),
        );
        let t2 = eg.add_with_data(
            ENode::new(Op::Transpose { perm: vec![1, 0] }, vec![t1]),
            Shape::new(DType::F32, vec![2, 3]),
            false,
            crate::ir::NodeId(2),
        );
        saturate(&mut eg);
        assert!(eg.same(x, t2));
        assert!(!eg.same(x, t1));
    }

    #[test]
    fn noop_reshape_collapses() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[4, 4]);
        let r = eg.add_with_data(
            ENode::new(Op::Reshape { dims: vec![4, 4] }, vec![x]),
            Shape::new(DType::F32, vec![4, 4]),
            false,
            crate::ir::NodeId(1),
        );
        saturate(&mut eg);
        assert!(eg.same(x, r));
    }

    #[test]
    fn reshape_chain_collapses() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[4, 4]);
        let r1 = eg.add_with_data(
            ENode::new(Op::Reshape { dims: vec![16] }, vec![x]),
            Shape::new(DType::F32, vec![16]),
            false,
            crate::ir::NodeId(1),
        );
        let r2 = eg.add_with_data(
            ENode::new(Op::Reshape { dims: vec![2, 8] }, vec![r1]),
            Shape::new(DType::F32, vec![2, 8]),
            false,
            crate::ir::NodeId(2),
        );
        let direct = eg.add_with_data(
            ENode::new(Op::Reshape { dims: vec![2, 8] }, vec![x]),
            Shape::new(DType::F32, vec![2, 8]),
            false,
            crate::ir::NodeId(3),
        );
        saturate(&mut eg);
        assert!(eg.same(r2, direct));
    }

    #[test]
    fn commutativity() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[2]);
        let y = leaf(&mut eg, "y", &[2]);
        let xy = eg.add(ENode::new(Op::Add, vec![x, y]));
        let yx = eg.add(ENode::new(Op::Add, vec![y, x]));
        saturate(&mut eg);
        assert!(eg.same(xy, yx));
        // subtraction must NOT commute
        let sub_xy = eg.add(ENode::new(Op::Sub, vec![x, y]));
        let sub_yx = eg.add(ENode::new(Op::Sub, vec![y, x]));
        saturate(&mut eg);
        assert!(!eg.same(sub_xy, sub_yx));
    }

    #[test]
    fn const_folding() {
        let mut eg = EGraph::new();
        let a = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(3.0)), vec![]));
        let b = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(4.0)), vec![]));
        let sum = eg.add(ENode::new(Op::Add, vec![a, b]));
        let direct = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(7.0)), vec![]));
        saturate(&mut eg);
        assert!(eg.same(sum, direct));
        // rsqrt(4) = 0.5
        let four = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(4.0)), vec![]));
        let rs = eg.add(ENode::new(Op::Rsqrt, vec![four]));
        let half = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(0.5)), vec![]));
        saturate(&mut eg);
        assert!(eg.same(rs, half));
    }

    #[test]
    fn div_equals_mul_reciprocal() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[2, 2]);
        let two = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(2.0)), vec![]));
        let btwo = eg.add_with_data(
            ENode::new(Op::Broadcast { mapped: vec![], dims: vec![2, 2] }, vec![two]),
            Shape::new(DType::F32, vec![2, 2]),
            false,
            crate::ir::NodeId(1),
        );
        let div = eg.add(ENode::new(Op::Div, vec![x, btwo]));
        let half = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(0.5)), vec![]));
        let bhalf = eg.add_with_data(
            ENode::new(Op::Broadcast { mapped: vec![], dims: vec![2, 2] }, vec![half]),
            Shape::new(DType::F32, vec![2, 2]),
            false,
            crate::ir::NodeId(2),
        );
        let mul = eg.add(ENode::new(Op::Mul, vec![x, bhalf]));
        saturate(&mut eg);
        assert!(eg.same(div, mul));
    }

    #[test]
    fn slice_reassembly_full_cover() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[4, 6]);
        let s1 = eg.add_with_data(
            ENode::new(
                Op::Slice { starts: vec![0, 0], limits: vec![4, 3], strides: vec![1, 1] },
                vec![x],
            ),
            Shape::new(DType::F32, vec![4, 3]),
            false,
            crate::ir::NodeId(1),
        );
        let s2 = eg.add_with_data(
            ENode::new(
                Op::Slice { starts: vec![0, 3], limits: vec![4, 6], strides: vec![1, 1] },
                vec![x],
            ),
            Shape::new(DType::F32, vec![4, 3]),
            false,
            crate::ir::NodeId(2),
        );
        let cat = eg.add(ENode::new(Op::Concat { dim: 1 }, vec![s1, s2]));
        saturate(&mut eg);
        assert!(eg.same(cat, x));
        // partial cover must NOT reassemble
        let cat_partial = eg.add(ENode::new(Op::Concat { dim: 1 }, vec![s1, s1]));
        saturate(&mut eg);
        assert!(!eg.same(cat_partial, x));
    }

    #[test]
    fn convert_chain_precision_guard() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[2]); // f32
        // f32 -> bf16 -> f32 must NOT collapse to x
        let lo = eg.add_with_data(
            ENode::new(Op::Convert { to: DType::BF16 }, vec![x]),
            Shape::new(DType::BF16, vec![2]),
            false,
            crate::ir::NodeId(1),
        );
        let back = eg.add_with_data(
            ENode::new(Op::Convert { to: DType::F32 }, vec![lo]),
            Shape::new(DType::F32, vec![2]),
            false,
            crate::ir::NodeId(2),
        );
        // f32 -> f64 -> f32 CAN collapse (no truncation inward)
        let up = eg.add_with_data(
            ENode::new(Op::Convert { to: DType::F64 }, vec![x]),
            Shape::new(DType::F64, vec![2]),
            false,
            crate::ir::NodeId(3),
        );
        let down = eg.add_with_data(
            ENode::new(Op::Convert { to: DType::F32 }, vec![up]),
            Shape::new(DType::F32, vec![2]),
            false,
            crate::ir::NodeId(4),
        );
        saturate(&mut eg);
        assert!(!eg.same(x, back), "bf16 round-trip must stay distinct");
        assert!(eg.same(x, down), "f64 round-trip collapses");
    }

    #[test]
    fn identity_elim() {
        let mut eg = EGraph::new();
        let x = leaf(&mut eg, "x", &[2]);
        let zero = eg.add(ENode::new(Op::Constant(ConstVal::Scalar(0.0)), vec![]));
        let bz = eg.add_with_data(
            ENode::new(Op::Broadcast { mapped: vec![], dims: vec![2] }, vec![zero]),
            Shape::new(DType::F32, vec![2]),
            false,
            crate::ir::NodeId(1),
        );
        let sum = eg.add(ENode::new(Op::Add, vec![x, bz]));
        saturate(&mut eg);
        assert!(eg.same(sum, x));
    }
}
