//! Minimal JSON value, writer and parser.
//!
//! The offline build carries no `serde`; the report types need exactly
//! this much JSON: objects with ordered keys, arrays, strings (with
//! escapes), finite numbers, booleans and null. The parser is a strict
//! recursive-descent reader for the same subset, so every document the
//! writer emits round-trips.

use crate::error::{Result, ScalifyError};
use std::fmt::Write as _;

/// A JSON value with insertion-ordered object keys.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// A finite number (non-finite values serialize as `null`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; keys keep insertion order for stable output.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric payload as u64 (must be a non-negative integer).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Bool payload.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array payload.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// String field of an object (`get` + `as_str`). The wire protocol
    /// reads fields this way throughout.
    pub fn str_at(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// Numeric field of an object as `u64`.
    pub fn u64_at(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(Json::as_u64)
    }

    /// Numeric field of an object.
    pub fn f64_at(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    /// Bool field of an object.
    pub fn bool_at(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Json::as_bool)
    }

    /// Serialize compactly (no insignificant whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation.
    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| out.push_str(&"  ".repeat(n));
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(fields) if !fields.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document (exactly one value plus whitespace).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(text, bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(ScalifyError::parse(format!(
                "trailing characters after JSON value at byte {pos}"
            )));
        }
        Ok(value)
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, b: u8) -> Result<()> {
    if *pos < bytes.len() && bytes[*pos] == b {
        *pos += 1;
        Ok(())
    } else {
        Err(ScalifyError::parse(format!(
            "expected '{}' at byte {} of JSON input",
            b as char, *pos
        )))
    }
}

/// Nesting bound: malformed/hostile input must yield a typed error, not a
/// stack overflow (report documents nest 3-4 deep in practice).
const MAX_DEPTH: usize = 128;

fn parse_value(text: &str, bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json> {
    if depth > MAX_DEPTH {
        return Err(ScalifyError::parse(format!(
            "JSON nests deeper than {MAX_DEPTH} levels"
        )));
    }
    skip_ws(bytes, pos);
    let Some(&b) = bytes.get(*pos) else {
        return Err(ScalifyError::parse("unexpected end of JSON input"));
    };
    match b {
        b'n' => parse_lit(bytes, pos, "null", Json::Null),
        b't' => parse_lit(bytes, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(bytes, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(text, bytes, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(text, bytes, pos, depth + 1)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => {
                        return Err(ScalifyError::parse(format!(
                            "expected ',' or ']' at byte {} of JSON input",
                            *pos
                        )))
                    }
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(text, bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, b':')?;
                let value = parse_value(text, bytes, pos, depth + 1)?;
                fields.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => {
                        return Err(ScalifyError::parse(format!(
                            "expected ',' or '}}' at byte {} of JSON input",
                            *pos
                        )))
                    }
                }
            }
        }
        _ => parse_number(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(ScalifyError::parse(format!(
            "invalid literal at byte {} of JSON input (expected '{lit}')",
            *pos
        )))
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).expect("ascii number run");
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| ScalifyError::parse(format!("invalid JSON number '{text}' at byte {start}")))
}

fn parse_string(text: &str, bytes: &[u8], pos: &mut usize) -> Result<String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&b) = bytes.get(*pos) else {
            return Err(ScalifyError::parse("unterminated JSON string"));
        };
        match b {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                let Some(&esc) = bytes.get(*pos) else {
                    return Err(ScalifyError::parse("unterminated JSON escape"));
                };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex = bytes
                            .get(*pos..*pos + 4)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| ScalifyError::parse("truncated \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| ScalifyError::parse(format!("bad \\u escape '{hex}'")))?;
                        *pos += 4;
                        // surrogate pairs are not emitted by our writer;
                        // map lone surrogates to the replacement character
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => {
                        return Err(ScalifyError::parse(format!(
                            "unknown JSON escape '\\{}'",
                            other as char
                        )))
                    }
                }
            }
            _ => {
                // consume one full UTF-8 character from the source text
                let s = &text[*pos..];
                let c = s.chars().next().expect("in-bounds char");
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_parses_scalars() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(0.5).render(), "0.5");
        assert_eq!(Json::Str("a\"b\n".into()).render(), "\"a\\\"b\\n\"");
        assert_eq!(Json::parse("  42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("\"hé\\u0041\"").unwrap(), Json::Str("héA".into()));
    }

    #[test]
    fn round_trips_nested_documents() {
        let doc = Json::Obj(vec![
            ("verdict".into(), Json::Str("verified".into())),
            (
                "layers".into(),
                Json::Arr(vec![
                    Json::Obj(vec![
                        ("layer".into(), Json::Num(0.0)),
                        ("memoized".into(), Json::Bool(false)),
                    ]),
                    Json::Obj(vec![
                        ("layer".into(), Json::Num(1.0)),
                        ("memoized".into(), Json::Bool(true)),
                    ]),
                ]),
            ),
            ("total_secs".into(), Json::Num(0.125)),
            ("note".into(), Json::Null),
        ]);
        let compact = doc.render();
        assert_eq!(Json::parse(&compact).unwrap(), doc);
        let pretty = doc.render_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), doc);
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_is_a_typed_error_not_a_stack_overflow() {
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.to_string().contains("nests deeper"), "{err}");
        // nesting at the limit still parses
        let ok = format!("{}{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn get_and_accessors() {
        let doc = Json::parse("{\"a\": [1, true, \"x\"], \"b\": 7}").unwrap();
        assert_eq!(doc.get("b").and_then(Json::as_u64), Some(7));
        let arr = doc.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[1].as_bool(), Some(true));
        assert_eq!(arr[2].as_str(), Some("x"));
        assert_eq!(doc.get("missing"), None);
    }

    #[test]
    fn keyed_accessors() {
        let doc =
            Json::parse("{\"s\": \"hi\", \"n\": 3, \"f\": 0.5, \"b\": false}").unwrap();
        assert_eq!(doc.str_at("s"), Some("hi"));
        assert_eq!(doc.u64_at("n"), Some(3));
        assert_eq!(doc.f64_at("f"), Some(0.5));
        assert_eq!(doc.bool_at("b"), Some(false));
        assert_eq!(doc.str_at("n"), None);
        assert_eq!(doc.u64_at("missing"), None);
        // non-objects yield None, not panics
        assert_eq!(Json::Num(1.0).str_at("s"), None);
    }
}
