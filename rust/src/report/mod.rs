//! Report emitters: aligned text tables + CSV for every experiment, and
//! the machine-readable JSON encoding of [`VerifyReport`] the CLI's
//! `--json` flag and embedding services consume.

pub mod json;

use crate::egraph::RuleStat;
use crate::error::{Result, ScalifyError};
use crate::ir::ReduceKind;
use crate::localize::Discrepancy;
use crate::verifier::boundary::RelSummary;
use crate::verifier::{LayerReport, Verdict, VerifyReport};
use json::Json;
use std::fmt::Write;
use std::time::Duration;

// The persisted verification-state artifact lives next to the report
// codecs: `verify --emit-state` writes one, `verify --against` reads one.
pub use crate::diff::state::{LayerState, VerifyState};

fn secs(d: Duration) -> Json {
    Json::Num(d.as_secs_f64())
}

fn field<'j>(doc: &'j Json, key: &str) -> Result<&'j Json> {
    doc.get(key)
        .ok_or_else(|| ScalifyError::parse(format!("report JSON missing field '{key}'")))
}

fn str_field(doc: &Json, key: &str) -> Result<String> {
    field(doc, key)?
        .as_str()
        .map(str::to_owned)
        .ok_or_else(|| ScalifyError::parse(format!("report field '{key}' is not a string")))
}

fn num_field(doc: &Json, key: &str) -> Result<f64> {
    field(doc, key)?
        .as_f64()
        .ok_or_else(|| ScalifyError::parse(format!("report field '{key}' is not a number")))
}

fn bool_field(doc: &Json, key: &str) -> Result<bool> {
    field(doc, key)?
        .as_bool()
        .ok_or_else(|| ScalifyError::parse(format!("report field '{key}' is not a bool")))
}

impl Discrepancy {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("dist_node".into(), Json::Num(self.dist_node.0 as f64)),
            ("site".into(), Json::Str(self.site.clone())),
            ("func".into(), Json::Str(self.func.clone())),
            ("expr".into(), Json::Str(self.expr.clone())),
            ("reason".into(), Json::Str(self.reason.clone())),
            (
                "layer".into(),
                self.layer.map(|l| Json::Num(l as f64)).unwrap_or(Json::Null),
            ),
        ])
    }

    /// Decode from [`Discrepancy::to_json`] output.
    pub fn from_json(doc: &Json) -> Result<Discrepancy> {
        Ok(Discrepancy {
            dist_node: crate::ir::NodeId(num_field(doc, "dist_node")? as u32),
            site: str_field(doc, "site")?,
            func: str_field(doc, "func")?,
            expr: str_field(doc, "expr")?,
            reason: str_field(doc, "reason")?,
            layer: match field(doc, "layer")? {
                Json::Null => None,
                v => Some(v.as_f64().ok_or_else(|| {
                    ScalifyError::parse("report field 'layer' is not a number or null")
                })? as u32),
            },
        })
    }
}

/// Content checksum over the compact rendering of a JSON document.
/// Parsing + re-rendering is canonical (insertion-ordered objects,
/// integer numbers), so loaders recompute and compare: a flipped digit
/// in a persisted fingerprint fails the check and degrades to a cold
/// start instead of replaying a proof for the wrong layer. Shared by the
/// service memo cache and the diff [`VerifyState`].
pub fn json_checksum(doc: &Json) -> String {
    use std::hash::Hasher as _;
    let mut h = crate::partition::StableHasher::new();
    h.write(doc.render().as_bytes());
    format!("{:016x}", h.finish())
}

/// Wire encoding of a boundary relation summary (shared by the service
/// memo cache and the diff [`VerifyState`] — same format on disk).
pub fn rel_summary_to_json(rel: &RelSummary) -> Json {
    match rel {
        RelSummary::Duplicate => {
            Json::Obj(vec![("rel".into(), Json::Str("duplicate".into()))])
        }
        RelSummary::Sharded { dim, parts, axis } => Json::Obj(vec![
            ("rel".into(), Json::Str("sharded".into())),
            ("dim".into(), Json::Num(*dim as f64)),
            ("parts".into(), Json::Num(*parts as f64)),
            ("axis".into(), Json::Num(*axis as f64)),
        ]),
        RelSummary::MeshSharded { entries } => Json::Obj(vec![
            ("rel".into(), Json::Str("mesh-sharded".into())),
            (
                "entries".into(),
                Json::Arr(
                    entries
                        .iter()
                        .map(|&(d, p, a)| {
                            Json::Arr(vec![
                                Json::Num(d as f64),
                                Json::Num(p as f64),
                                Json::Num(a as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        RelSummary::Partial { kind, axes } => Json::Obj(vec![
            ("rel".into(), Json::Str("partial".into())),
            ("reduce".into(), Json::Str(reduce_label(*kind).into())),
            ("axes".into(), Json::Num(*axes as f64)),
        ]),
    }
}

/// Decode a boundary relation summary; error strings are caller-facing
/// ("why did this store degrade to a cold start").
pub fn rel_summary_from_json(doc: &Json) -> std::result::Result<RelSummary, String> {
    match doc.str_at("rel").ok_or("relation is missing 'rel'")? {
        "duplicate" => Ok(RelSummary::Duplicate),
        "sharded" => Ok(RelSummary::Sharded {
            dim: doc.u64_at("dim").ok_or("sharded relation is missing 'dim'")? as usize,
            parts: doc.u64_at("parts").ok_or("sharded relation is missing 'parts'")?
                as u32,
            // absent in pre-mesh captures; those are rejected by the
            // fingerprint-version gate before this parser ever runs
            axis: doc.u64_at("axis").unwrap_or(0) as usize,
        }),
        "mesh-sharded" => {
            let entries = doc
                .get("entries")
                .and_then(Json::as_arr)
                .ok_or("mesh-sharded relation is missing 'entries'")?
                .iter()
                .map(|e| {
                    let triple = e.as_arr().filter(|t| t.len() == 3).ok_or_else(|| {
                        "mesh-sharded entry is not a [dim, parts, axis] triple".to_string()
                    })?;
                    let num = |j: &Json| -> std::result::Result<u64, String> {
                        match j {
                            Json::Num(n) if *n >= 0.0 => Ok(*n as u64),
                            _ => Err("mesh-sharded entry is not numeric".into()),
                        }
                    };
                    Ok((
                        num(&triple[0])? as usize,
                        num(&triple[1])? as u32,
                        num(&triple[2])? as usize,
                    ))
                })
                .collect::<std::result::Result<Vec<_>, String>>()?;
            Ok(RelSummary::MeshSharded { entries })
        }
        "partial" => Ok(RelSummary::Partial {
            kind: parse_reduce(
                doc.str_at("reduce").ok_or("partial relation is missing 'reduce'")?,
            )?,
            axes: doc.u64_at("axes").unwrap_or(1) as crate::ir::AxesMask,
        }),
        other => Err(format!("unknown relation kind '{other}'")),
    }
}

fn reduce_label(kind: ReduceKind) -> &'static str {
    match kind {
        ReduceKind::Add => "add",
        ReduceKind::Max => "max",
        ReduceKind::Min => "min",
        ReduceKind::Mul => "mul",
    }
}

fn parse_reduce(label: &str) -> std::result::Result<ReduceKind, String> {
    match label {
        "add" => Ok(ReduceKind::Add),
        "max" => Ok(ReduceKind::Max),
        "min" => Ok(ReduceKind::Min),
        "mul" => Ok(ReduceKind::Mul),
        other => Err(format!("unknown reduce kind '{other}'")),
    }
}

/// JSON encoding of one per-rule counter row.
pub fn rule_stat_to_json(r: &RuleStat) -> Json {
    Json::Obj(vec![
        ("name".into(), Json::Str(r.name.clone())),
        ("matches_tried".into(), Json::Num(r.matches_tried as f64)),
        ("matches".into(), Json::Num(r.matches as f64)),
        ("applications".into(), Json::Num(r.applications as f64)),
        ("time_secs".into(), secs(r.time)),
        ("banned_iters".into(), Json::Num(r.banned_iters as f64)),
    ])
}

/// Decode one per-rule counter row.
pub fn rule_stat_from_json(doc: &Json) -> Result<RuleStat> {
    Ok(RuleStat {
        name: str_field(doc, "name")?,
        matches_tried: num_field(doc, "matches_tried")? as usize,
        matches: num_field(doc, "matches")? as usize,
        applications: num_field(doc, "applications")? as usize,
        time: Duration::from_secs_f64(num_field(doc, "time_secs")?.max(0.0)),
        banned_iters: num_field(doc, "banned_iters")? as usize,
    })
}

impl LayerReport {
    /// JSON encoding.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("layer".into(), Json::Num(self.layer as f64)),
            (
                "stage".into(),
                self.stage.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null),
            ),
            ("verified".into(), Json::Bool(self.verified)),
            ("memoized".into(), Json::Bool(self.memoized)),
            ("reused".into(), Json::Bool(self.reused)),
            ("reverified".into(), Json::Bool(self.reverified)),
            ("delta_nodes".into(), Json::Num(self.delta_nodes as f64)),
            ("egraph_nodes".into(), Json::Num(self.egraph_nodes as f64)),
            ("egraph_classes".into(), Json::Num(self.egraph_classes as f64)),
            ("facts".into(), Json::Num(self.facts as f64)),
            ("matches_tried".into(), Json::Num(self.matches_tried as f64)),
            (
                "rules".into(),
                Json::Arr(self.rules.iter().map(rule_stat_to_json).collect()),
            ),
            ("duration_secs".into(), secs(self.duration)),
        ])
    }

    /// Decode from [`LayerReport::to_json`] output.
    ///
    /// Only `layer` and `verified` are hard requirements: every counter
    /// added since the first schema decodes with a zero default, so a
    /// capture from any prior release loads (and captures from *newer*
    /// releases load here because unknown keys are simply never looked
    /// at). The explicit fixtures in the test module pin this contract
    /// per schema generation.
    pub fn from_json(doc: &Json) -> Result<LayerReport> {
        Ok(LayerReport {
            layer: num_field(doc, "layer")? as u32,
            // optional for compatibility with pre-pipeline captures
            stage: doc.get("stage").and_then(Json::as_f64).map(|s| s as u32),
            verified: bool_field(doc, "verified")?,
            memoized: doc.get("memoized").and_then(Json::as_bool).unwrap_or(false),
            // diff-aware fields: absent in pre-incremental captures
            reused: doc.get("reused").and_then(Json::as_bool).unwrap_or(false),
            reverified: doc.get("reverified").and_then(Json::as_bool).unwrap_or(false),
            delta_nodes: doc.get("delta_nodes").and_then(Json::as_f64).unwrap_or(0.0)
                as usize,
            egraph_nodes: doc.get("egraph_nodes").and_then(Json::as_f64).unwrap_or(0.0)
                as usize,
            // counter fields below are optional for compatibility with
            // captures written before the indexed-matcher widening
            egraph_classes: doc.get("egraph_classes").and_then(Json::as_f64).unwrap_or(0.0)
                as usize,
            facts: doc.get("facts").and_then(Json::as_f64).unwrap_or(0.0) as usize,
            matches_tried: doc.get("matches_tried").and_then(Json::as_f64).unwrap_or(0.0)
                as usize,
            rules: match doc.get("rules").and_then(Json::as_arr) {
                Some(arr) => arr
                    .iter()
                    .map(rule_stat_from_json)
                    .collect::<Result<Vec<_>>>()?,
                None => vec![],
            },
            duration: Duration::from_secs_f64(
                doc.get("duration_secs").and_then(Json::as_f64).unwrap_or(0.0).max(0.0),
            ),
        })
    }
}

impl Verdict {
    /// Stable status label (`verified` / `unverified` / `resource-exhausted`).
    pub fn status(&self) -> &'static str {
        match self {
            Verdict::Verified => "verified",
            Verdict::Unverified { .. } => "unverified",
            Verdict::ResourceExhausted { .. } => "resource-exhausted",
        }
    }
}

impl VerifyReport {
    /// JSON encoding of the full report (verdict, discrepancies, per-layer
    /// stats, phase timings).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("status".into(), Json::Str(self.verdict.status().into())),
            ("verified".into(), Json::Bool(self.verified())),
        ];
        if let Verdict::ResourceExhausted { at } = &self.verdict {
            fields.push(("exhausted_at".into(), Json::Str(at.clone())));
        }
        // only emitted on degraded runs, so non-degraded renders stay
        // byte-identical to pre-degradation captures
        if self.degraded {
            fields.push(("degraded".into(), Json::Bool(true)));
            if let Some(at) = &self.first_unverified {
                fields.push(("first_unverified".into(), Json::Str(at.clone())));
            }
        }
        fields.push((
            "discrepancies".into(),
            Json::Arr(self.discrepancies().iter().map(Discrepancy::to_json).collect()),
        ));
        fields.push((
            "layers".into(),
            Json::Arr(self.layers.iter().map(LayerReport::to_json).collect()),
        ));
        fields.push((
            "phases".into(),
            Json::Obj(
                self.stopwatch
                    .phases()
                    .map(|(name, d)| (name.to_owned(), secs(d)))
                    .collect(),
            ),
        ));
        fields.push(("total_secs".into(), secs(self.total)));
        Json::Obj(fields)
    }

    /// Serialize to a pretty-printed JSON string.
    pub fn to_json_string(&self) -> String {
        self.to_json().render_pretty()
    }

    /// Decode a report from [`VerifyReport::to_json`] output (e.g. a
    /// `scalify --json` capture); verdict, discrepancies, layer stats and
    /// timings all survive the round trip.
    pub fn from_json(doc: &Json) -> Result<VerifyReport> {
        let status = str_field(doc, "status")?;
        let discrepancies = field(doc, "discrepancies")?
            .as_arr()
            .ok_or_else(|| ScalifyError::parse("report field 'discrepancies' is not an array"))?
            .iter()
            .map(Discrepancy::from_json)
            .collect::<Result<Vec<_>>>()?;
        let verdict = match status.as_str() {
            "verified" => Verdict::Verified,
            "unverified" => Verdict::Unverified { discrepancies },
            "resource-exhausted" => {
                Verdict::ResourceExhausted { at: str_field(doc, "exhausted_at")? }
            }
            other => {
                return Err(ScalifyError::parse(format!("unknown report status '{other}'")))
            }
        };
        let layers = field(doc, "layers")?
            .as_arr()
            .ok_or_else(|| ScalifyError::parse("report field 'layers' is not an array"))?
            .iter()
            .map(LayerReport::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut stopwatch = crate::util::Stopwatch::new();
        if let Json::Obj(phases) = field(doc, "phases")? {
            for (name, v) in phases {
                let d = v.as_f64().ok_or_else(|| {
                    ScalifyError::parse(format!("phase '{name}' duration is not a number"))
                })?;
                stopwatch.record(name, Duration::from_secs_f64(d.max(0.0)));
            }
        }
        Ok(VerifyReport {
            verdict,
            layers,
            stopwatch,
            total: Duration::from_secs_f64(num_field(doc, "total_secs")?.max(0.0)),
            degraded: doc.bool_at("degraded").unwrap_or(false),
            first_unverified: doc.str_at("first_unverified").map(str::to_string),
        })
    }

    /// Parse a JSON string produced by [`VerifyReport::to_json_string`].
    pub fn from_json_str(text: &str) -> Result<VerifyReport> {
        VerifyReport::from_json(&Json::parse(text)?)
    }
}

/// A simple aligned text table with optional CSV dump.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Render aligned text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        writeln!(out, "== {} ==", self.title).unwrap();
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        writeln!(out, "{}", fmt_row(&self.headers)).unwrap();
        writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len())).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", fmt_row(row)).unwrap();
        }
        out
    }

    /// Render CSV.
    pub fn csv(&self) -> String {
        let mut out = String::new();
        writeln!(out, "{}", self.headers.join(",")).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", row.join(",")).unwrap();
        }
        out
    }

    /// Write CSV next to the bench outputs.
    pub fn save_csv(&self, name: &str) {
        let dir = std::path::Path::new("target/reports");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(format!("{name}.csv")), self.csv());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_report_json_round_trips() {
        let report = VerifyReport {
            verdict: Verdict::Unverified {
                discrepancies: vec![Discrepancy {
                    dist_node: crate::ir::NodeId(17),
                    site: "attention.py:42".into(),
                    func: "flash_decoding".into(),
                    expr: "all_reduce(x)".into(),
                    reason: "no relation derived".into(),
                    layer: Some(3),
                }],
            },
            layers: vec![LayerReport {
                layer: 3,
                stage: Some(1),
                verified: false,
                memoized: false,
                reused: true,
                reverified: false,
                delta_nodes: 9,
                egraph_nodes: 120,
                egraph_classes: 61,
                facts: 44,
                matches_tried: 512,
                rules: vec![RuleStat {
                    name: "transpose-fusion".into(),
                    matches_tried: 256,
                    matches: 12,
                    applications: 3,
                    time: Duration::from_micros(150),
                    banned_iters: 1,
                }],
                duration: Duration::from_millis(7),
            }],
            stopwatch: {
                let mut sw = crate::util::Stopwatch::new();
                sw.record("partition", Duration::from_millis(1));
                sw.record("verify-layers", Duration::from_millis(6));
                sw
            },
            total: Duration::from_millis(8),
            degraded: true,
            first_unverified: Some("layer 4".into()),
        };
        let text = report.to_json_string();
        let back = VerifyReport::from_json_str(&text).unwrap();
        assert!(back.degraded);
        assert_eq!(back.first_unverified.as_deref(), Some("layer 4"));
        assert_eq!(back.verdict.status(), report.verdict.status());
        assert_eq!(back.verified(), report.verified());
        assert_eq!(back.discrepancies().len(), 1);
        assert_eq!(back.discrepancies()[0].site, "attention.py:42");
        assert_eq!(back.discrepancies()[0].layer, Some(3));
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.layers[0].egraph_nodes, 120);
        assert_eq!(back.layers[0].egraph_classes, 61);
        assert_eq!(back.layers[0].matches_tried, 512);
        assert_eq!(back.layers[0].rules, report.layers[0].rules);
        assert_eq!(back.layers[0].stage, Some(1));
        assert_eq!(back.layers[0].reused, true);
        assert_eq!(back.layers[0].reverified, false);
        assert_eq!(back.layers[0].delta_nodes, 9);
        assert_eq!(back.total, report.total);
        assert_eq!(back.stopwatch.phases().count(), 2);
    }

    /// One literal layer fixture per schema generation. Every prior
    /// schema must keep loading (back compat), and documents carrying
    /// keys this reader has never heard of must load too (forward
    /// compat — an old reader pointed at a new report ignores the new
    /// `VerifyState`-era fields the same way).
    #[test]
    fn layer_report_loads_every_prior_schema_generation() {
        // v1 (pre-pipeline): layer/verified/memoized/egraph_nodes/facts/duration
        let v1 = r#"{"layer":3,"verified":true,"memoized":false,
                     "egraph_nodes":10,"facts":4,"duration_secs":0.5}"#;
        // v2 (+stage, nullable)
        let v2 = r#"{"layer":3,"stage":1,"verified":true,"memoized":true,
                     "egraph_nodes":10,"facts":4,"duration_secs":0.5}"#;
        // v3 (+indexed-matcher counters: egraph_classes/matches_tried/rules)
        let v3 = r#"{"layer":3,"stage":null,"verified":true,"memoized":false,
                     "egraph_nodes":10,"egraph_classes":5,"facts":4,
                     "matches_tried":77,"rules":[],"duration_secs":0.5}"#;
        // v4 (+diff-aware fields: reused/reverified/delta_nodes)
        let v4 = r#"{"layer":3,"stage":null,"verified":true,"memoized":false,
                     "reused":true,"reverified":false,"delta_nodes":2,
                     "egraph_nodes":10,"egraph_classes":5,"facts":4,
                     "matches_tried":77,"rules":[],"duration_secs":0.5}"#;
        for (gen, text) in [(1, v1), (2, v2), (3, v3), (4, v4)] {
            let doc = Json::parse(text).unwrap();
            let layer = LayerReport::from_json(&doc)
                .unwrap_or_else(|e| panic!("schema generation {gen} must load: {e}"));
            assert_eq!(layer.layer, 3);
            assert!(layer.verified);
        }
        // pre-diff generations default the diff fields
        let doc = Json::parse(v3).unwrap();
        let layer = LayerReport::from_json(&doc).unwrap();
        assert!(!layer.reused && !layer.reverified);
        assert_eq!(layer.delta_nodes, 0);
        // forward compat: unknown fields from some future schema are
        // ignored, not an error
        let future = r#"{"layer":3,"verified":true,"from_the_future":{"x":[1,2]},
                         "another_unknown":"ok"}"#;
        let layer = LayerReport::from_json(&Json::parse(future).unwrap()).unwrap();
        assert_eq!(layer.layer, 3);
        assert_eq!(layer.facts, 0, "missing counters default to zero");
    }

    #[test]
    fn full_report_from_a_pre_incremental_capture_loads() {
        // a minimal whole-report document as an old release wrote it:
        // no reused/reverified/delta_nodes anywhere
        let text = r#"{
            "status": "verified", "verified": true, "discrepancies": [],
            "layers": [{"layer":0,"verified":true,"memoized":false,
                        "egraph_nodes":12,"facts":3,"duration_secs":0.01}],
            "phases": {"partition": 0.001, "verify-layers": 0.009},
            "total_secs": 0.011
        }"#;
        let report = VerifyReport::from_json_str(text).unwrap();
        assert!(report.verified());
        assert_eq!(report.layers.len(), 1);
        assert!(!report.layers[0].reused);
    }

    #[test]
    fn rel_summary_wire_codec_round_trips() {
        let rels = vec![
            RelSummary::Duplicate,
            RelSummary::Sharded { dim: 1, parts: 4, axis: 1 },
            RelSummary::MeshSharded { entries: vec![(0, 2, 0), (1, 4, 1)] },
            RelSummary::Partial { kind: ReduceKind::Max, axes: 0b11 },
        ];
        for rel in &rels {
            let back = rel_summary_from_json(&rel_summary_to_json(rel)).unwrap();
            assert_eq!(&back, rel);
        }
    }

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(&["1".into(), "2".into(), "3".into()]);
        t.row(&["xxx".into(), "y".into(), "zzzz".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-header"));
        let csv = t.csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("a,long-header,c"));
    }
}
