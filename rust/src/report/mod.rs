//! Table/figure emitters: aligned text tables + CSV for every experiment.

use std::fmt::Write;

/// A simple aligned text table with optional CSV dump.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Render aligned text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        writeln!(out, "== {} ==", self.title).unwrap();
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        writeln!(out, "{}", fmt_row(&self.headers)).unwrap();
        writeln!(out, "{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len())).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", fmt_row(row)).unwrap();
        }
        out
    }

    /// Render CSV.
    pub fn csv(&self) -> String {
        let mut out = String::new();
        writeln!(out, "{}", self.headers.join(",")).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", row.join(",")).unwrap();
        }
        out
    }

    /// Write CSV next to the bench outputs.
    pub fn save_csv(&self, name: &str) {
        let dir = std::path::Path::new("target/reports");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(format!("{name}.csv")), self.csv());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-header", "c"]);
        t.row(&["1".into(), "2".into(), "3".into()]);
        t.row(&["xxx".into(), "y".into(), "zzzz".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-header"));
        let csv = t.csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("a,long-header,c"));
    }
}
